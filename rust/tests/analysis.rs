//! Static-analysis tests: the abstract interpreter must accept every
//! program the real emitters produce (seeded acceptance sweeps over
//! conv/GEMM shape space), reject every mutation class with a
//! descriptive violation — safety defects via the abstract
//! interpreter, semantic defects via the term-provenance equivalence
//! layer — and prove the paper's workloads stay inside the f32
//! exact-integer accumulator range end to end.

use soniq::analysis::{
    self, elem_prod_max, lane_mac_max, verify_program, verify_program_full, EquivVerifier,
    KernelSpec, KernelVerifier, ModelVerdict, ShardAxis, TermSpec, VerifyReport, Violation,
    F32_EXACT_BOUND,
};
use soniq::codegen::gemm::{emit_gemm, emit_gemm_causal, GemmPlan};
use soniq::codegen::{self, DataFormat, LayerBufs, LayerKind, LayerPlan};
use soniq::coordinator::{paperscale, synthetic_network, DesignPoint};
use soniq::serve::{DeployConfig, Deployment, KvPoolCfg, ModelKey};
use soniq::simd::isa::{Addr, BufId, Instr};
use soniq::simd::patterns::{design_subset, Pattern};
use soniq::smol::pattern_match::{pattern_match, Assignment};
use soniq::util::prop::check;
use soniq::util::rng::Rng;

/// The symbolic buffer convention every spec/emitter pair shares:
/// 0 = input, 1 = weights, 2 = out, 3 = masks.
fn bufs() -> LayerBufs {
    LayerBufs { input: BufId(0), weights: BufId(1), out: BufId(2), masks: BufId(3) }
}

fn a(buf: u16, off: u32) -> Addr {
    Addr { buf: BufId(buf), off }
}

/// The same assignment mix the synthetic nets draw from: uniform SMOL
/// levels plus pattern-matched mixed-precision under P4/P8 subsets.
fn rand_assignment(rng: &mut Rng, cin: usize) -> Assignment {
    match rng.below(5) {
        0 => Assignment::uniform(cin, 1),
        1 => Assignment::uniform(cin, 2),
        2 => Assignment::uniform(cin, 4),
        d => {
            let s: Vec<f32> = (0..cin).map(|_| rng.range(-3.0, 6.0)).collect();
            let np = if d == 3 { 4 } else { 8 };
            pattern_match(&s, &design_subset(np))
        }
    }
}

fn rand_format(rng: &mut Rng) -> DataFormat {
    match rng.below(6) {
        0 => DataFormat::Int8,
        1 => DataFormat::Fp32,
        _ => DataFormat::Smol,
    }
}

fn smol_gemm(m: usize, k: usize, n: usize, asg: Assignment) -> (KernelSpec, Vec<Instr>) {
    let plan = GemmPlan { name: "mutant".into(), m, k, n, asg, fmt: DataFormat::Smol };
    let spec = KernelSpec::for_gemm(&plan);
    let mut program = Vec::new();
    emit_gemm(&plan, &bufs(), 0, &mut program);
    (spec, program)
}

fn violations_str(m: &ModelVerdict) -> String {
    m.violations().map(|(w, v)| format!("[{w}] {v}")).collect::<Vec<_>>().join("; ")
}

#[test]
fn worst_case_bound_constants() {
    // the 2^-6-grid element products and the lane_sums_fit_16_6 values
    assert_eq!(elem_prod_max(4), 225);
    assert_eq!(elem_prod_max(2), 144);
    assert_eq!(elem_prod_max(1), 64);
    assert_eq!(lane_mac_max(4), 900);
    assert_eq!(lane_mac_max(2), 1152);
    assert_eq!(lane_mac_max(1), 1024);
}

// ---------------------------------------------------------------------
// Acceptance: the verifier proves every emitter-produced program clean.
// ---------------------------------------------------------------------

#[test]
fn prop_conv_emitter_programs_verify_clean() {
    check("analysis-conv-sweep", 300, |rng| {
        let cin = 1 + rng.below(64) as usize;
        let depthwise = rng.below(4) == 0;
        let cout = if depthwise { cin } else { 1 + rng.below(8) as usize };
        let kk = *rng.choice(&[1usize, 3]);
        let plan = LayerPlan {
            name: "conv-sweep".into(),
            kind: if depthwise { LayerKind::Depthwise } else { LayerKind::Dense },
            cin,
            cout,
            kh: kk,
            kw: kk,
            stride: *rng.choice(&[1usize, 2]),
            hin: 1 + rng.below(5) as usize,
            win: 1 + rng.below(5) as usize,
            asg: rand_assignment(rng, cin),
            fmt: rand_format(rng),
        };
        let spec = KernelSpec::for_layer(&plan);
        let terms = TermSpec::for_layer(&plan);
        if (plan.fmt == DataFormat::Smol) != terms.is_some() {
            return Err("term-spec derivability must track the SMOL format".into());
        }
        let mut program = Vec::new();
        codegen::emit_layer(&plan, &bufs(), 0, &mut program);
        let verdict = verify_program_full(&spec, terms.as_ref(), &program);
        if !verdict.is_clean() {
            return Err(format!(
                "cin={cin} cout={cout} k={kk} {:?} {:?}: {:?}",
                plan.kind,
                plan.fmt,
                verdict.violations.first()
            ));
        }
        if verdict.instrs != program.len() as u64 {
            return Err("verifier did not walk the whole program".into());
        }
        // at these shapes (<= 9 taps, <= 8 chunks) every SMOL kernel
        // must stay far inside the exact-integer range
        if plan.fmt == DataFormat::Smol && !verdict.f32_exact() {
            return Err(format!("bound {} escapes 2^24", verdict.max_acc_bound));
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_emitter_programs_verify_clean() {
    check("analysis-gemm-sweep", 300, |rng| {
        let k = 1 + rng.below(64) as usize;
        let m = 1 + rng.below(12) as usize;
        let causal = rng.below(3) == 0;
        let n = if causal { m } else { 1 + rng.below(12) as usize };
        let plan = GemmPlan {
            name: "gemm-sweep".into(),
            m,
            k,
            n,
            asg: rand_assignment(rng, k),
            fmt: rand_format(rng),
        };
        let spec = KernelSpec::for_gemm(&plan);
        let terms = TermSpec::for_gemm(&plan, causal);
        if (plan.fmt == DataFormat::Smol) != terms.is_some() {
            return Err("term-spec derivability must track the SMOL format".into());
        }
        let mut program = Vec::new();
        if causal {
            emit_gemm_causal(&plan, &bufs(), 0, &mut program);
        } else {
            emit_gemm(&plan, &bufs(), 0, &mut program);
        }
        let verdict = verify_program_full(&spec, terms.as_ref(), &program);
        if !verdict.is_clean() {
            return Err(format!(
                "m={m} k={k} n={n} causal={causal} {:?}: {:?}",
                plan.fmt,
                verdict.violations.first()
            ));
        }
        if plan.fmt == DataFormat::Smol && !verdict.f32_exact() {
            return Err(format!("bound {} escapes 2^24", verdict.max_acc_bound));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Mutations: each corruption class must be caught and named.
// ---------------------------------------------------------------------

#[test]
fn mutated_buf_id_is_rejected() {
    let (spec, mut program) = smol_gemm(2, 64, 2, Assignment::uniform(64, 2));
    assert!(verify_program(&spec, &program).is_clean());
    for i in program.iter_mut() {
        if let Instr::LdQ { addr, .. } = i {
            if addr.buf.0 == 1 {
                addr.buf = BufId(9);
            }
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::BadBuf { buf: 9, .. })),
        "{:?}",
        verdict.violations
    );
}

#[test]
fn corrupted_offset_is_rejected() {
    // push one load 1 MiB past the buffer: still 16-aligned and (with a
    // single chunk) provenance-preserving, so the *only* new defect is
    // the bounds escape
    let (spec, clean) = smol_gemm(2, 64, 2, Assignment::uniform(64, 2));
    let mut program = clean.clone();
    for i in program.iter_mut() {
        if let Instr::LdQ { addr, .. } = i {
            addr.off += 1 << 20;
            break;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::OutOfBounds { .. })),
        "{:?}",
        verdict.violations
    );
    assert!(!verdict.violations.iter().any(|v| matches!(v, Violation::Misaligned { .. })));

    // nudge the first (offset-0) load by 4 bytes: alignment breaks, but
    // the 20-byte reach stays inside the 32-byte operand buffer
    let mut program = clean;
    for i in program.iter_mut() {
        if let Instr::LdQ { addr, .. } = i {
            assert_eq!(addr.off, 0);
            addr.off = 4;
            break;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::Misaligned { align: 16, .. })),
        "{:?}",
        verdict.violations
    );
    assert!(!verdict.violations.iter().any(|v| matches!(v, Violation::OutOfBounds { .. })));
}

#[test]
fn swapped_pattern_id_is_rejected() {
    // two full chunks with *different* patterns, so a PatId swap is a
    // real layout mismatch rather than a harmless relabeling
    let asg = Assignment {
        chunks: vec![Pattern::uniform(4), Pattern::uniform(2)],
        valid: vec![32, 64],
        precision: [vec![4u8; 32], vec![2u8; 64]].concat(),
        order: (0..96).collect(),
    };
    let (spec, clean) = smol_gemm(1, 96, 2, asg);
    assert!(verify_program(&spec, &clean).is_clean());

    let mut program = clean.clone();
    for i in program.iter_mut() {
        if let Instr::VmacP { pat, .. } = i {
            *pat = 1 - *pat;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::PatternMismatch { .. })),
        "{:?}",
        verdict.violations
    );

    let mut program = clean;
    for i in program.iter_mut() {
        if let Instr::VmacP { pat, .. } = i {
            *pat = 77;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::BadPatId { pat: 77, table: 2, .. })),
        "{:?}",
        verdict.violations
    );
}

#[test]
fn widened_contraction_escapes_exact_range() {
    // 16320 channels at 2 bits is 255 full chunks; a 3x3 window's center
    // output accumulates 255 chunks x 9 taps x 9216 = 21,150,720 — past
    // 2^24 (so bit-exact sharded reduction is no longer guaranteed) but
    // still far from i32 overflow. The verifier must prove exactly that.
    let cin = 16320;
    let plan = LayerPlan {
        name: "wide-k".into(),
        kind: LayerKind::Dense,
        cin,
        cout: 1,
        kh: 3,
        kw: 3,
        stride: 1,
        hin: 3,
        win: 3,
        asg: Assignment::uniform(cin, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_layer(&plan);
    let mut v = KernelVerifier::new(&spec);
    codegen::emit_layer(&plan, &bufs(), 0, &mut v);
    let verdict = v.finish();
    assert_eq!(verdict.max_acc_bound, 255 * 9 * 9216);
    assert!(verdict.max_acc_bound > F32_EXACT_BOUND);
    assert!(verdict.max_acc_bound <= i32::MAX as i64);
    assert_eq!(verdict.violations.len(), 1, "{:?}", verdict.violations);
    assert!(matches!(
        verdict.violations[0],
        Violation::AccExactRange { bound: 21_150_720, limit: F32_EXACT_BOUND }
    ));
}

#[test]
fn lane_accumulation_overflow_is_rejected() {
    // 29 stacked vaddq_s16 of a uniform-2 MAC result: 29 x 1152 = 33,408
    // crosses i16::MAX on the final add (28 x 1152 = 32,256 does not)
    let plan = GemmPlan {
        name: "lane-stack".into(),
        m: 1,
        k: 64,
        n: 1,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let mut program = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
        Instr::VmovZ { dst: 3 },
    ];
    for _ in 0..28 {
        program.push(Instr::Vaddq16 { dst: 3, a: 3, b: 2 });
    }
    assert!(verify_program(&spec, &program).is_clean());
    program.push(Instr::Vaddq16 { dst: 3, a: 3, b: 2 });
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| matches!(v, Violation::LaneOverflow { bound: 33_408, .. })),
        "{:?}",
        verdict.violations
    );
}

#[test]
fn cell_accumulator_overflow_is_rejected() {
    // no real emitter can reach i32 overflow (the 255-entry pattern
    // table caps the contraction first), so drive the running cell sum
    // over the line by hand: one MAC result reduced into one cell until
    // 9216 * n > i32::MAX
    let plan = GemmPlan {
        name: "acc-overflow".into(),
        m: 1,
        k: 64,
        n: 1,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let mut program = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
    ];
    let n = (i32::MAX as i64 / 9216) as usize + 2;
    for _ in 0..n {
        program.push(Instr::ReduceAcc { src: 2, addr: a(2, 0) });
    }
    let verdict = verify_program(&spec, &program);
    let overflows = verdict
        .violations
        .iter()
        .filter(|v| matches!(v, Violation::AccOverflow { buf: 2, off: 0, .. }))
        .count();
    // deduped: one report per cell, not one per crossing instruction
    assert_eq!(overflows, 1, "{:?}", verdict.violations);
    assert!(verdict.violations.iter().any(|v| matches!(v, Violation::AccExactRange { .. })));
}

#[test]
fn unmasked_tail_is_rejected_masked_is_accepted() {
    // 8 valid channels in a 64-capacity uniform-2 chunk: a partial
    // chunk, so the input operand must pass through vand before a MAC
    let plan = GemmPlan {
        name: "tail".into(),
        m: 1,
        k: 8,
        n: 1,
        asg: Assignment::uniform(8, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let unmasked = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
    ];
    let verdict = verify_program(&spec, &unmasked);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::UnmaskedTail { chunk: 0, .. })),
        "{:?}",
        verdict.violations
    );

    let masked = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 3, addr: a(3, 0) },
        Instr::Vand { dst: 4, a: 0, b: 3 },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        // weights are pre-masked at pack time — only the input needs vand
        Instr::VmacP { dst: 2, a: 4, b: 1, pat: 0 },
        Instr::VmovZ { dst: 5 },
        Instr::Vaddq16 { dst: 5, a: 5, b: 2 },
        Instr::ReduceAcc { src: 5, addr: a(2, 0) },
    ];
    let verdict = verify_program(&spec, &masked);
    assert!(verdict.is_clean(), "{:?}", verdict.violations);
    assert_eq!(verdict.max_acc_bound, 8 * 1152);
}

#[test]
fn undefined_and_bad_registers_are_rejected() {
    let plan = GemmPlan {
        name: "regs".into(),
        m: 1,
        k: 64,
        n: 1,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let program = vec![Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 }, Instr::VmovZ { dst: 40 }];
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::UndefinedReg { reg: 0, .. })),
        "{:?}",
        verdict.violations
    );
    assert!(verdict.violations.iter().any(|v| matches!(v, Violation::UndefinedReg { reg: 1, .. })));
    assert!(verdict.violations.iter().any(|v| matches!(v, Violation::BadReg { reg: 40, .. })));
}

#[test]
fn mul_acc_n_valid_beyond_capacity_is_rejected() {
    let plan = LayerPlan {
        name: "dw-nvalid".into(),
        kind: LayerKind::Depthwise,
        cin: 8,
        cout: 8,
        kh: 1,
        kw: 1,
        stride: 1,
        hin: 1,
        win: 1,
        asg: Assignment::uniform(8, 4),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_layer(&plan);
    let mut program = Vec::new();
    codegen::emit_layer(&plan, &bufs(), 0, &mut program);
    assert!(verify_program(&spec, &program).is_clean());
    for i in program.iter_mut() {
        if let Instr::MulAcc { n_valid, .. } = i {
            *n_valid = 200;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| matches!(v, Violation::NValidExceedsCapacity { n_valid: 200, capacity: 32, .. })),
        "{:?}",
        verdict.violations
    );
}

// ---------------------------------------------------------------------
// Equivalence mutations: semantic defects the safety layer cannot see
// must be caught by term provenance with their exact violation class.
// ---------------------------------------------------------------------

/// [`smol_gemm`] plus the plan-derived [`TermSpec`] the equivalence
/// layer checks the program against.
fn smol_gemm_full(
    m: usize,
    k: usize,
    n: usize,
    asg: Assignment,
) -> (KernelSpec, TermSpec, Vec<Instr>) {
    let plan = GemmPlan { name: "mutant".into(), m, k, n, asg, fmt: DataFormat::Smol };
    let spec = KernelSpec::for_gemm(&plan);
    let terms = TermSpec::for_gemm(&plan, false).expect("SMOL GEMMs always have a term spec");
    let mut program = Vec::new();
    emit_gemm(&plan, &bufs(), 0, &mut program);
    (spec, terms, program)
}

#[test]
fn dropped_mac_is_missing_terms() {
    let (spec, terms, clean) = smol_gemm_full(2, 64, 2, Assignment::uniform(64, 2));
    assert!(verify_program_full(&spec, Some(&terms), &clean).is_clean());

    // drop cell 0's only VmacP/ReduceAcc pair
    let mut program = clean;
    let i = program.iter().position(|x| matches!(x, Instr::VmacP { .. })).unwrap();
    assert!(matches!(program[i + 1], Instr::ReduceAcc { .. }));
    program.drain(i..i + 2);

    // the safety layer proves the shortened program perfectly safe...
    assert!(verify_program(&spec, &program).is_clean());
    // ...only term provenance sees cell 0 lost its whole contraction
    let verdict = verify_program_full(&spec, Some(&terms), &program);
    let missing = verdict
        .violations
        .iter()
        .filter(|v| matches!(v, Violation::MissingTerm { cell: 0, tap: 0, .. }))
        .count();
    assert_eq!(missing, 64, "{:?}", verdict.violations.first());
}

#[test]
fn duplicated_mac_is_duplicate_terms() {
    let (spec, terms, clean) = smol_gemm_full(2, 64, 2, Assignment::uniform(64, 2));
    let mut program = clean;
    let i = program.iter().position(|x| matches!(x, Instr::VmacP { .. })).unwrap();
    let (mac, red) = (program[i], program[i + 1]);
    assert!(matches!(red, Instr::ReduceAcc { .. }));
    program.insert(i, mac);
    program.insert(i + 1, red);

    assert!(verify_program(&spec, &program).is_clean());
    let verdict = verify_program_full(&spec, Some(&terms), &program);
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| matches!(v, Violation::DuplicateTerm { cell: 0, channel: 0, tap: 0, .. })),
        "{:?}",
        verdict.violations.first()
    );
    assert!(!verdict.violations.iter().any(|v| matches!(v, Violation::MissingTerm { .. })));
}

#[test]
fn swapped_activation_rows_are_foreign_terms() {
    // swap the two A-row loads of the same chunk: chunk- and
    // pattern-coherent, so the safety layer is completely blind — only
    // provenance ties a loaded row to the cell it reduces into
    let (spec, terms, clean) = smol_gemm_full(2, 64, 1, Assignment::uniform(64, 2));
    let mut program = clean;
    let loads: Vec<usize> = program
        .iter()
        .enumerate()
        .filter(|(_, x)| matches!(x, Instr::LdQ { addr, .. } if addr.buf.0 == 0))
        .map(|(i, _)| i)
        .collect();
    assert_eq!(loads.len(), 2);
    for (li, off) in loads.iter().zip([16u32, 0]) {
        if let Instr::LdQ { addr, .. } = &mut program[*li] {
            addr.off = off;
        }
    }

    assert!(verify_program(&spec, &program).is_clean());
    let verdict = verify_program_full(&spec, Some(&terms), &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::ForeignTerm { cell: 0, .. })),
        "{:?}",
        verdict.violations.first()
    );
}

#[test]
fn skipped_tail_vand_is_unmasked_tail_term() {
    // 8 valid channels in a 64-capacity chunk, with every Vand removed
    let (spec, terms, clean) = smol_gemm_full(1, 8, 1, Assignment::uniform(8, 2));
    assert!(verify_program_full(&spec, Some(&terms), &clean).is_clean());
    let mut program = clean;
    program.retain(|x| !matches!(x, Instr::Vand { .. }));

    let verdict = verify_program_full(&spec, Some(&terms), &program);
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| matches!(v, Violation::UnmaskedTailTerm { cell: 0, chunk: 0, .. })),
        "{:?}",
        verdict.violations.first()
    );
    // and the masked-MAC ledger comes up short of the tail bias the
    // engine epilogue subtracts
    assert!(verdict.violations.iter().any(|v| matches!(
        v,
        Violation::EpilogueMismatch { cell: 0, chunk: 0, expected: 1, got: 0 }
    )));
}

#[test]
fn double_applied_tail_mac_is_epilogue_mismatch() {
    // duplicating a *partial* chunk's masked MAC corrupts the output
    // even though every lane stays masked: the epilogue subtracts one
    // tail bias but the tail contributed twice
    let (spec, terms, clean) = smol_gemm_full(1, 8, 1, Assignment::uniform(8, 2));
    let mut program = clean;
    let i = program.iter().position(|x| matches!(x, Instr::VmacP { .. })).unwrap();
    let (mac, red) = (program[i], program[i + 1]);
    assert!(matches!(red, Instr::ReduceAcc { .. }));
    program.insert(i, mac);
    program.insert(i + 1, red);

    assert!(verify_program(&spec, &program).is_clean());
    let verdict = verify_program_full(&spec, Some(&terms), &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(
            v,
            Violation::EpilogueMismatch { cell: 0, chunk: 0, expected: 1, got: 2 }
        )),
        "{:?}",
        verdict.violations.first()
    );
}

#[test]
fn widened_mul_acc_scatter_is_foreign_term() {
    // 40 channels @4b: a full 32-capacity chunk plus an 8-channel tail
    // chunk; two spatial positions leave the out buffer room for the
    // widened write, so the safety layer proves it in-bounds and
    // within pattern capacity — only the term layer knows the chunk
    // holds 8 channels
    let plan = LayerPlan {
        name: "dw-widen".into(),
        kind: LayerKind::Depthwise,
        cin: 40,
        cout: 40,
        kh: 1,
        kw: 1,
        stride: 1,
        hin: 2,
        win: 1,
        asg: Assignment::uniform(40, 4),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_layer(&plan);
    let terms = TermSpec::for_layer(&plan).unwrap();
    let mut program = Vec::new();
    codegen::emit_layer(&plan, &bufs(), 0, &mut program);
    assert!(verify_program_full(&spec, Some(&terms), &program).is_clean());

    let i = program
        .iter()
        .position(|x| matches!(x, Instr::MulAcc { n_valid: 8, .. }))
        .unwrap();
    if let Instr::MulAcc { n_valid, .. } = &mut program[i] {
        *n_valid = 9;
    }
    assert!(verify_program(&spec, &program).is_clean());
    let verdict = verify_program_full(&spec, Some(&terms), &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::ForeignTerm { cell: 40, .. })),
        "{:?}",
        verdict.violations.first()
    );
}

#[test]
fn shard_term_partition_accepts_slices_and_rejects_misoffsets() {
    let plan = GemmPlan {
        name: "part".into(),
        m: 3,
        k: 64,
        n: 16,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let whole = TermSpec::for_gemm(&plan, false).unwrap();

    // cout split — the deployment split-node check
    let lo = TermSpec::for_gemm(&plan.slice_n(0, 8), false).unwrap();
    let hi = TermSpec::for_gemm(&plan.slice_n(8, 16), false).unwrap();
    let good = [(lo.clone(), 0), (hi.clone(), 8)];
    assert!(analysis::shard_term_partition("n", &whole, &good, ShardAxis::OutputChannels)
        .is_empty());
    let wrong = [(lo, 0), (hi, 4)];
    let v = analysis::shard_term_partition("n", &whole, &wrong, ShardAxis::OutputChannels);
    assert!(v.iter().any(|x| matches!(x, Violation::ShardTermPartition { .. })), "{v:?}");

    // contraction split — the reduce-consumer check
    let klo = TermSpec::for_gemm(&plan.slice_k(0, 32), false).unwrap();
    let khi = TermSpec::for_gemm(&plan.slice_k(32, 64), false).unwrap();
    let good = [(klo.clone(), 0), (khi.clone(), 32)];
    assert!(analysis::shard_term_partition("k", &whole, &good, ShardAxis::Contraction).is_empty());
    let wrong = [(klo, 0), (khi, 16)];
    let v = analysis::shard_term_partition("k", &whole, &wrong, ShardAxis::Contraction);
    assert!(v.iter().any(|x| matches!(x, Violation::ShardTermPartition { .. })), "{v:?}");
}

// ---------------------------------------------------------------------
// Workloads: every serving model proves clean and f32-exact.
// ---------------------------------------------------------------------

/// Paper-scale layers verified by *streaming* the emitter into both
/// verifiers (nothing is materialized). Spatial extent and `cout` are
/// clamped (hin <= 6 covers a full 3x3 window at both strides, cout
/// <= 8 a full register block) because the per-cell accumulator bound —
/// sum over chunks of in-window taps x the chunk's pattern-wise lane
/// sums — does not depend on either axis; `cin`, the precision/chunk
/// axis the bound *does* depend on, is kept at full paper-scale width.
fn paperscale_verdict() -> ModelVerdict {
    let supported = design_subset(4);
    let mut verdict = ModelVerdict { name: "paperscale".into(), ..Default::default() };
    for model in ["resnet18", "mobilenetv2", "shufflenetv2"] {
        for shp in paperscale::shapes_for(model) {
            let depthwise = shp.groups > 1;
            let plan = LayerPlan {
                name: format!("{model}/{}", shp.name),
                kind: if depthwise { LayerKind::Depthwise } else { LayerKind::Dense },
                cin: shp.cin,
                cout: if depthwise { shp.cout } else { shp.cout.min(8) },
                kh: shp.k,
                kw: shp.k,
                stride: shp.stride,
                hin: shp.hin.min(6),
                win: shp.win.min(6),
                asg: paperscale::assignment_from_fractions(shp.cin, 0.25, 0.5, &supported),
                fmt: DataFormat::Smol,
            };
            let spec = KernelSpec::for_layer(&plan);
            let terms = TermSpec::for_layer(&plan).expect("paper-scale layers are SMOL");
            let mut v = KernelVerifier::new(&spec);
            codegen::emit_layer(&plan, &bufs(), 0, &mut v);
            let mut k = v.finish();
            // second streaming pass: term equivalence at the full
            // paper-scale contraction width
            let mut eq = EquivVerifier::new(&spec, &terms);
            codegen::emit_layer(&plan, &bufs(), 0, &mut eq);
            let e = eq.finish();
            k.violations.extend(e.violations);
            k.suppressed += e.suppressed;
            verdict.kernels.push(k);
        }
    }
    verdict
}

#[test]
fn all_workloads_verify_clean_within_f32_exact_range() {
    let mut report = VerifyReport::default();
    for name in ["tinynet", "tinydw", "tinyattn", "tinydec", "tinywide"] {
        let net = synthetic_network(name, DesignPoint::Patterns(4), 0).unwrap();
        let mut m = analysis::verify_model(name, &net.prepare());
        m.plan_violations.extend(analysis::verify_graph(&net.nodes, net.input_shape));
        if let (Some(step), Some(shape)) = (net.step_nodes.as_deref(), net.step_input_shape) {
            m.plan_violations.extend(analysis::verify_graph(step, shape));
        }
        report.models.push(m);
    }
    report.models.push(paperscale_verdict());

    assert_eq!(report.models.len(), 6);
    for m in &report.models {
        assert!(!m.kernels.is_empty(), "{}: no programs verified", m.name);
        assert!(m.is_clean(), "{}: {}", m.name, violations_str(m));
        assert!(
            m.max_acc_bound() <= F32_EXACT_BOUND,
            "{}: accumulator bound {} escapes the f32 exact-integer range",
            m.name,
            m.max_acc_bound()
        );
        for k in &m.kernels {
            assert!(k.f32_exact(), "{}: {} at bound {}", m.name, k.name, k.max_acc_bound);
        }
    }
    assert!(report.is_clean());
    assert_eq!(report.num_violations(), 0);
    let text = report.to_string();
    assert!(text.contains("verdict: CLEAN"), "{text}");
    assert!(!text.contains("2^24: NO"), "{text}");
}

#[test]
fn sharded_deployment_verifies_and_budget_violations_surface() {
    let net = synthetic_network("tinywide", DesignPoint::Patterns(4), 0).unwrap();
    let key = ModelKey::new("tinywide", DesignPoint::Patterns(4).label());
    let cfg = DeployConfig { worker_budget: None, shards: Some(2) };
    let dep = Deployment::build(key, &net.nodes, None, &cfg).unwrap();

    // clean includes the shard term-partition check: the slices' term
    // sets must tile the whole split node's exactly
    let verdicts = analysis::verify_deployment(&dep, &net.nodes, None);
    assert_eq!(verdicts.len(), 1 + dep.num_shards());
    for m in &verdicts {
        assert!(m.is_clean(), "{}: {}", m.name, violations_str(m));
    }

    // an absurdly tight budget must turn into per-shard violations
    let tight = analysis::verify_deployment(&dep, &net.nodes, Some(64));
    assert!(
        tight[0].plan_violations.iter().any(|v| matches!(v, Violation::BudgetExceeded { .. })),
        "{}",
        violations_str(&tight[0])
    );
}

#[test]
fn kv_geometry_accepts_real_decoders_and_rejects_bad_configs() {
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 0).unwrap();
    let prepared = net.prepare();
    let step = prepared.step.as_ref().expect("tinydec is a decoder");
    assert!(!step.slot_geoms.is_empty());

    assert!(analysis::verify_kv(&KvPoolCfg::default(), &step.slot_geoms).is_empty());
    let narrow = KvPoolCfg { v_bits: Some(1), ..KvPoolCfg::default() };
    assert!(analysis::verify_kv(&narrow, &step.slot_geoms).is_empty());

    let zero = KvPoolCfg { page_positions: 0, ..KvPoolCfg::default() };
    let v = analysis::verify_kv(&zero, &[]);
    assert!(v.iter().any(|x| matches!(x, Violation::PageGeometry { .. })), "{v:?}");

    let bad_bits = KvPoolCfg { v_bits: Some(3), ..KvPoolCfg::default() };
    let v = analysis::verify_kv(&bad_bits, &[]);
    assert!(v.iter().any(|x| matches!(x, Violation::PageGeometry { .. })), "{v:?}");
}

#[test]
fn graph_shape_defects_surface() {
    let net = synthetic_network("tinynet", DesignPoint::Patterns(4), 0).unwrap();
    assert!(analysis::verify_graph(&net.nodes, net.input_shape).is_empty());
    let (h, w, c) = net.input_shape;
    let v = analysis::verify_graph(&net.nodes, (h, w, c + 1));
    assert!(v.iter().any(|x| matches!(x, Violation::Graph { .. })), "{v:?}");
}
