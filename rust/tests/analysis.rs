//! Static-analysis tests: the abstract interpreter must accept every
//! program the real emitters produce (seeded acceptance sweeps over
//! conv/GEMM shape space), reject every mutation class with a
//! descriptive violation, and prove the paper's workloads stay inside
//! the f32 exact-integer accumulator range end to end.

use soniq::analysis::{
    self, elem_prod_max, lane_mac_max, verify_program, KernelSpec, KernelVerifier, ModelVerdict,
    VerifyReport, Violation, F32_EXACT_BOUND,
};
use soniq::codegen::gemm::{emit_gemm, emit_gemm_causal, GemmPlan};
use soniq::codegen::{self, DataFormat, LayerBufs, LayerKind, LayerPlan};
use soniq::coordinator::{paperscale, synthetic_network, DesignPoint};
use soniq::serve::{DeployConfig, Deployment, KvPoolCfg, ModelKey};
use soniq::simd::isa::{Addr, BufId, Instr};
use soniq::simd::patterns::{design_subset, Pattern};
use soniq::smol::pattern_match::{pattern_match, Assignment};
use soniq::util::prop::check;
use soniq::util::rng::Rng;

/// The symbolic buffer convention every spec/emitter pair shares:
/// 0 = input, 1 = weights, 2 = out, 3 = masks.
fn bufs() -> LayerBufs {
    LayerBufs { input: BufId(0), weights: BufId(1), out: BufId(2), masks: BufId(3) }
}

fn a(buf: u16, off: u32) -> Addr {
    Addr { buf: BufId(buf), off }
}

/// The same assignment mix the synthetic nets draw from: uniform SMOL
/// levels plus pattern-matched mixed-precision under P4/P8 subsets.
fn rand_assignment(rng: &mut Rng, cin: usize) -> Assignment {
    match rng.below(5) {
        0 => Assignment::uniform(cin, 1),
        1 => Assignment::uniform(cin, 2),
        2 => Assignment::uniform(cin, 4),
        d => {
            let s: Vec<f32> = (0..cin).map(|_| rng.range(-3.0, 6.0)).collect();
            let np = if d == 3 { 4 } else { 8 };
            pattern_match(&s, &design_subset(np))
        }
    }
}

fn rand_format(rng: &mut Rng) -> DataFormat {
    match rng.below(6) {
        0 => DataFormat::Int8,
        1 => DataFormat::Fp32,
        _ => DataFormat::Smol,
    }
}

fn smol_gemm(m: usize, k: usize, n: usize, asg: Assignment) -> (KernelSpec, Vec<Instr>) {
    let plan = GemmPlan { name: "mutant".into(), m, k, n, asg, fmt: DataFormat::Smol };
    let spec = KernelSpec::for_gemm(&plan);
    let mut program = Vec::new();
    emit_gemm(&plan, &bufs(), 0, &mut program);
    (spec, program)
}

fn violations_str(m: &ModelVerdict) -> String {
    m.violations().map(|(w, v)| format!("[{w}] {v}")).collect::<Vec<_>>().join("; ")
}

#[test]
fn worst_case_bound_constants() {
    // the 2^-6-grid element products and the lane_sums_fit_16_6 values
    assert_eq!(elem_prod_max(4), 225);
    assert_eq!(elem_prod_max(2), 144);
    assert_eq!(elem_prod_max(1), 64);
    assert_eq!(lane_mac_max(4), 900);
    assert_eq!(lane_mac_max(2), 1152);
    assert_eq!(lane_mac_max(1), 1024);
}

// ---------------------------------------------------------------------
// Acceptance: the verifier proves every emitter-produced program clean.
// ---------------------------------------------------------------------

#[test]
fn prop_conv_emitter_programs_verify_clean() {
    check("analysis-conv-sweep", 300, |rng| {
        let cin = 1 + rng.below(64) as usize;
        let depthwise = rng.below(4) == 0;
        let cout = if depthwise { cin } else { 1 + rng.below(8) as usize };
        let kk = *rng.choice(&[1usize, 3]);
        let plan = LayerPlan {
            name: "conv-sweep".into(),
            kind: if depthwise { LayerKind::Depthwise } else { LayerKind::Dense },
            cin,
            cout,
            kh: kk,
            kw: kk,
            stride: *rng.choice(&[1usize, 2]),
            hin: 1 + rng.below(5) as usize,
            win: 1 + rng.below(5) as usize,
            asg: rand_assignment(rng, cin),
            fmt: rand_format(rng),
        };
        let spec = KernelSpec::for_layer(&plan);
        let mut program = Vec::new();
        codegen::emit_layer(&plan, &bufs(), 0, &mut program);
        let verdict = verify_program(&spec, &program);
        if !verdict.is_clean() {
            return Err(format!(
                "cin={cin} cout={cout} k={kk} {:?} {:?}: {:?}",
                plan.kind,
                plan.fmt,
                verdict.violations.first()
            ));
        }
        if verdict.instrs != program.len() as u64 {
            return Err("verifier did not walk the whole program".into());
        }
        // at these shapes (<= 9 taps, <= 8 chunks) every SMOL kernel
        // must stay far inside the exact-integer range
        if plan.fmt == DataFormat::Smol && !verdict.f32_exact() {
            return Err(format!("bound {} escapes 2^24", verdict.max_acc_bound));
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_emitter_programs_verify_clean() {
    check("analysis-gemm-sweep", 300, |rng| {
        let k = 1 + rng.below(64) as usize;
        let m = 1 + rng.below(12) as usize;
        let causal = rng.below(3) == 0;
        let n = if causal { m } else { 1 + rng.below(12) as usize };
        let plan = GemmPlan {
            name: "gemm-sweep".into(),
            m,
            k,
            n,
            asg: rand_assignment(rng, k),
            fmt: rand_format(rng),
        };
        let spec = KernelSpec::for_gemm(&plan);
        let mut program = Vec::new();
        if causal {
            emit_gemm_causal(&plan, &bufs(), 0, &mut program);
        } else {
            emit_gemm(&plan, &bufs(), 0, &mut program);
        }
        let verdict = verify_program(&spec, &program);
        if !verdict.is_clean() {
            return Err(format!(
                "m={m} k={k} n={n} causal={causal} {:?}: {:?}",
                plan.fmt,
                verdict.violations.first()
            ));
        }
        if plan.fmt == DataFormat::Smol && !verdict.f32_exact() {
            return Err(format!("bound {} escapes 2^24", verdict.max_acc_bound));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Mutations: each corruption class must be caught and named.
// ---------------------------------------------------------------------

#[test]
fn mutated_buf_id_is_rejected() {
    let (spec, mut program) = smol_gemm(2, 64, 2, Assignment::uniform(64, 2));
    assert!(verify_program(&spec, &program).is_clean());
    for i in program.iter_mut() {
        if let Instr::LdQ { addr, .. } = i {
            if addr.buf.0 == 1 {
                addr.buf = BufId(9);
            }
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::BadBuf { buf: 9, .. })),
        "{:?}",
        verdict.violations
    );
}

#[test]
fn corrupted_offset_is_rejected() {
    // push one load 1 MiB past the buffer: still 16-aligned and (with a
    // single chunk) provenance-preserving, so the *only* new defect is
    // the bounds escape
    let (spec, clean) = smol_gemm(2, 64, 2, Assignment::uniform(64, 2));
    let mut program = clean.clone();
    for i in program.iter_mut() {
        if let Instr::LdQ { addr, .. } = i {
            addr.off += 1 << 20;
            break;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::OutOfBounds { .. })),
        "{:?}",
        verdict.violations
    );
    assert!(!verdict.violations.iter().any(|v| matches!(v, Violation::Misaligned { .. })));

    // nudge the first (offset-0) load by 4 bytes: alignment breaks, but
    // the 20-byte reach stays inside the 32-byte operand buffer
    let mut program = clean;
    for i in program.iter_mut() {
        if let Instr::LdQ { addr, .. } = i {
            assert_eq!(addr.off, 0);
            addr.off = 4;
            break;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::Misaligned { align: 16, .. })),
        "{:?}",
        verdict.violations
    );
    assert!(!verdict.violations.iter().any(|v| matches!(v, Violation::OutOfBounds { .. })));
}

#[test]
fn swapped_pattern_id_is_rejected() {
    // two full chunks with *different* patterns, so a PatId swap is a
    // real layout mismatch rather than a harmless relabeling
    let asg = Assignment {
        chunks: vec![Pattern::uniform(4), Pattern::uniform(2)],
        valid: vec![32, 64],
        precision: [vec![4u8; 32], vec![2u8; 64]].concat(),
        order: (0..96).collect(),
    };
    let (spec, clean) = smol_gemm(1, 96, 2, asg);
    assert!(verify_program(&spec, &clean).is_clean());

    let mut program = clean.clone();
    for i in program.iter_mut() {
        if let Instr::VmacP { pat, .. } = i {
            *pat = 1 - *pat;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::PatternMismatch { .. })),
        "{:?}",
        verdict.violations
    );

    let mut program = clean;
    for i in program.iter_mut() {
        if let Instr::VmacP { pat, .. } = i {
            *pat = 77;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::BadPatId { pat: 77, table: 2, .. })),
        "{:?}",
        verdict.violations
    );
}

#[test]
fn widened_contraction_escapes_exact_range() {
    // 16320 channels at 2 bits is 255 full chunks; a 3x3 window's center
    // output accumulates 255 chunks x 9 taps x 9216 = 21,150,720 — past
    // 2^24 (so bit-exact sharded reduction is no longer guaranteed) but
    // still far from i32 overflow. The verifier must prove exactly that.
    let cin = 16320;
    let plan = LayerPlan {
        name: "wide-k".into(),
        kind: LayerKind::Dense,
        cin,
        cout: 1,
        kh: 3,
        kw: 3,
        stride: 1,
        hin: 3,
        win: 3,
        asg: Assignment::uniform(cin, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_layer(&plan);
    let mut v = KernelVerifier::new(&spec);
    codegen::emit_layer(&plan, &bufs(), 0, &mut v);
    let verdict = v.finish();
    assert_eq!(verdict.max_acc_bound, 255 * 9 * 9216);
    assert!(verdict.max_acc_bound > F32_EXACT_BOUND);
    assert!(verdict.max_acc_bound <= i32::MAX as i64);
    assert_eq!(verdict.violations.len(), 1, "{:?}", verdict.violations);
    assert!(matches!(
        verdict.violations[0],
        Violation::AccExactRange { bound: 21_150_720, limit: F32_EXACT_BOUND }
    ));
}

#[test]
fn lane_accumulation_overflow_is_rejected() {
    // 29 stacked vaddq_s16 of a uniform-2 MAC result: 29 x 1152 = 33,408
    // crosses i16::MAX on the final add (28 x 1152 = 32,256 does not)
    let plan = GemmPlan {
        name: "lane-stack".into(),
        m: 1,
        k: 64,
        n: 1,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let mut program = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
        Instr::VmovZ { dst: 3 },
    ];
    for _ in 0..28 {
        program.push(Instr::Vaddq16 { dst: 3, a: 3, b: 2 });
    }
    assert!(verify_program(&spec, &program).is_clean());
    program.push(Instr::Vaddq16 { dst: 3, a: 3, b: 2 });
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| matches!(v, Violation::LaneOverflow { bound: 33_408, .. })),
        "{:?}",
        verdict.violations
    );
}

#[test]
fn cell_accumulator_overflow_is_rejected() {
    // no real emitter can reach i32 overflow (the 255-entry pattern
    // table caps the contraction first), so drive the running cell sum
    // over the line by hand: one MAC result reduced into one cell until
    // 9216 * n > i32::MAX
    let plan = GemmPlan {
        name: "acc-overflow".into(),
        m: 1,
        k: 64,
        n: 1,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let mut program = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
    ];
    let n = (i32::MAX as i64 / 9216) as usize + 2;
    for _ in 0..n {
        program.push(Instr::ReduceAcc { src: 2, addr: a(2, 0) });
    }
    let verdict = verify_program(&spec, &program);
    let overflows = verdict
        .violations
        .iter()
        .filter(|v| matches!(v, Violation::AccOverflow { buf: 2, off: 0, .. }))
        .count();
    // deduped: one report per cell, not one per crossing instruction
    assert_eq!(overflows, 1, "{:?}", verdict.violations);
    assert!(verdict.violations.iter().any(|v| matches!(v, Violation::AccExactRange { .. })));
}

#[test]
fn unmasked_tail_is_rejected_masked_is_accepted() {
    // 8 valid channels in a 64-capacity uniform-2 chunk: a partial
    // chunk, so the input operand must pass through vand before a MAC
    let plan = GemmPlan {
        name: "tail".into(),
        m: 1,
        k: 8,
        n: 1,
        asg: Assignment::uniform(8, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let unmasked = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
    ];
    let verdict = verify_program(&spec, &unmasked);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::UnmaskedTail { chunk: 0, .. })),
        "{:?}",
        verdict.violations
    );

    let masked = vec![
        Instr::LdQ { dst: 0, addr: a(0, 0) },
        Instr::LdQ { dst: 3, addr: a(3, 0) },
        Instr::Vand { dst: 4, a: 0, b: 3 },
        Instr::LdQ { dst: 1, addr: a(1, 0) },
        // weights are pre-masked at pack time — only the input needs vand
        Instr::VmacP { dst: 2, a: 4, b: 1, pat: 0 },
        Instr::VmovZ { dst: 5 },
        Instr::Vaddq16 { dst: 5, a: 5, b: 2 },
        Instr::ReduceAcc { src: 5, addr: a(2, 0) },
    ];
    let verdict = verify_program(&spec, &masked);
    assert!(verdict.is_clean(), "{:?}", verdict.violations);
    assert_eq!(verdict.max_acc_bound, 8 * 1152);
}

#[test]
fn undefined_and_bad_registers_are_rejected() {
    let plan = GemmPlan {
        name: "regs".into(),
        m: 1,
        k: 64,
        n: 1,
        asg: Assignment::uniform(64, 2),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_gemm(&plan);
    let program = vec![Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 }, Instr::VmovZ { dst: 40 }];
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict.violations.iter().any(|v| matches!(v, Violation::UndefinedReg { reg: 0, .. })),
        "{:?}",
        verdict.violations
    );
    assert!(verdict.violations.iter().any(|v| matches!(v, Violation::UndefinedReg { reg: 1, .. })));
    assert!(verdict.violations.iter().any(|v| matches!(v, Violation::BadReg { reg: 40, .. })));
}

#[test]
fn mul_acc_n_valid_beyond_capacity_is_rejected() {
    let plan = LayerPlan {
        name: "dw-nvalid".into(),
        kind: LayerKind::Depthwise,
        cin: 8,
        cout: 8,
        kh: 1,
        kw: 1,
        stride: 1,
        hin: 1,
        win: 1,
        asg: Assignment::uniform(8, 4),
        fmt: DataFormat::Smol,
    };
    let spec = KernelSpec::for_layer(&plan);
    let mut program = Vec::new();
    codegen::emit_layer(&plan, &bufs(), 0, &mut program);
    assert!(verify_program(&spec, &program).is_clean());
    for i in program.iter_mut() {
        if let Instr::MulAcc { n_valid, .. } = i {
            *n_valid = 200;
        }
    }
    let verdict = verify_program(&spec, &program);
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| matches!(v, Violation::NValidExceedsCapacity { n_valid: 200, capacity: 32, .. })),
        "{:?}",
        verdict.violations
    );
}

// ---------------------------------------------------------------------
// Workloads: every serving model proves clean and f32-exact.
// ---------------------------------------------------------------------

/// Paper-scale layers verified by *streaming* the emitter into the
/// verifier (nothing is materialized). Spatial extent and `cout` are
/// clamped (hin <= 6 covers a full 3x3 window at both strides, cout
/// <= 8 a full register block) because the per-cell accumulator bound —
/// sum over chunks of in-window taps x the chunk's pattern-wise lane
/// sums — does not depend on either axis; `cin`, the precision/chunk
/// axis the bound *does* depend on, is kept at full paper-scale width.
fn paperscale_verdict() -> ModelVerdict {
    let supported = design_subset(4);
    let mut verdict = ModelVerdict { name: "paperscale".into(), ..Default::default() };
    for model in ["resnet18", "mobilenetv2", "shufflenetv2"] {
        for shp in paperscale::shapes_for(model) {
            let depthwise = shp.groups > 1;
            let plan = LayerPlan {
                name: format!("{model}/{}", shp.name),
                kind: if depthwise { LayerKind::Depthwise } else { LayerKind::Dense },
                cin: shp.cin,
                cout: if depthwise { shp.cout } else { shp.cout.min(8) },
                kh: shp.k,
                kw: shp.k,
                stride: shp.stride,
                hin: shp.hin.min(6),
                win: shp.win.min(6),
                asg: paperscale::assignment_from_fractions(shp.cin, 0.25, 0.5, &supported),
                fmt: DataFormat::Smol,
            };
            let spec = KernelSpec::for_layer(&plan);
            let mut v = KernelVerifier::new(&spec);
            codegen::emit_layer(&plan, &bufs(), 0, &mut v);
            verdict.kernels.push(v.finish());
        }
    }
    verdict
}

#[test]
fn all_workloads_verify_clean_within_f32_exact_range() {
    let mut report = VerifyReport::default();
    for name in ["tinynet", "tinydw", "tinyattn", "tinydec", "tinywide"] {
        let net = synthetic_network(name, DesignPoint::Patterns(4), 0).unwrap();
        let mut m = analysis::verify_model(name, &net.prepare());
        m.plan_violations.extend(analysis::verify_graph(&net.nodes, net.input_shape));
        if let (Some(step), Some(shape)) = (net.step_nodes.as_deref(), net.step_input_shape) {
            m.plan_violations.extend(analysis::verify_graph(step, shape));
        }
        report.models.push(m);
    }
    report.models.push(paperscale_verdict());

    assert_eq!(report.models.len(), 6);
    for m in &report.models {
        assert!(!m.kernels.is_empty(), "{}: no programs verified", m.name);
        assert!(m.is_clean(), "{}: {}", m.name, violations_str(m));
        assert!(
            m.max_acc_bound() <= F32_EXACT_BOUND,
            "{}: accumulator bound {} escapes the f32 exact-integer range",
            m.name,
            m.max_acc_bound()
        );
        for k in &m.kernels {
            assert!(k.f32_exact(), "{}: {} at bound {}", m.name, k.name, k.max_acc_bound);
        }
    }
    assert!(report.is_clean());
    assert_eq!(report.num_violations(), 0);
    let text = report.to_string();
    assert!(text.contains("verdict: CLEAN"), "{text}");
    assert!(!text.contains("2^24: NO"), "{text}");
}

#[test]
fn sharded_deployment_verifies_and_budget_violations_surface() {
    let net = synthetic_network("tinywide", DesignPoint::Patterns(4), 0).unwrap();
    let key = ModelKey::new("tinywide", DesignPoint::Patterns(4).label());
    let cfg = DeployConfig { worker_budget: None, shards: Some(2) };
    let dep = Deployment::build(key, &net.nodes, None, &cfg).unwrap();

    let verdicts = analysis::verify_deployment(&dep, &net.nodes, None);
    assert_eq!(verdicts.len(), 1 + dep.num_shards());
    for m in &verdicts {
        assert!(m.is_clean(), "{}: {}", m.name, violations_str(m));
    }

    // an absurdly tight budget must turn into per-shard violations
    let tight = analysis::verify_deployment(&dep, &net.nodes, Some(64));
    assert!(
        tight[0].plan_violations.iter().any(|v| matches!(v, Violation::BudgetExceeded { .. })),
        "{}",
        violations_str(&tight[0])
    );
}

#[test]
fn kv_geometry_accepts_real_decoders_and_rejects_bad_configs() {
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 0).unwrap();
    let prepared = net.prepare();
    let step = prepared.step.as_ref().expect("tinydec is a decoder");
    assert!(!step.slot_geoms.is_empty());

    assert!(analysis::verify_kv(&KvPoolCfg::default(), &step.slot_geoms).is_empty());
    let narrow = KvPoolCfg { v_bits: Some(1), ..KvPoolCfg::default() };
    assert!(analysis::verify_kv(&narrow, &step.slot_geoms).is_empty());

    let zero = KvPoolCfg { page_positions: 0, ..KvPoolCfg::default() };
    let v = analysis::verify_kv(&zero, &[]);
    assert!(v.iter().any(|x| matches!(x, Violation::PageGeometry { .. })), "{v:?}");

    let bad_bits = KvPoolCfg { v_bits: Some(3), ..KvPoolCfg::default() };
    let v = analysis::verify_kv(&bad_bits, &[]);
    assert!(v.iter().any(|x| matches!(x, Violation::PageGeometry { .. })), "{v:?}");
}

#[test]
fn graph_shape_defects_surface() {
    let net = synthetic_network("tinynet", DesignPoint::Patterns(4), 0).unwrap();
    assert!(analysis::verify_graph(&net.nodes, net.input_shape).is_empty());
    let (h, w, c) = net.input_shape;
    let v = analysis::verify_graph(&net.nodes, (h, w, c + 1));
    assert!(v.iter().any(|x| matches!(x, Violation::Graph { .. })), "{v:?}");
}
