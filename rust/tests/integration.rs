//! Cross-module integration tests that do not need the PJRT runtime:
//! pattern selection -> pattern matching -> packing -> codegen ->
//! simulation pipelines on realistic layer shapes, plus meta.json / init
//! binary loading and graph building when artifacts are present.

use soniq::codegen::{DataFormat, LayerKind, LayerPlan};
use soniq::sim::machine::Machine;
use soniq::sim::network::{run_conv, ConvLayerCfg, Tensor};
use soniq::simd::patterns::{all_patterns, design_subset};
use soniq::smol::pattern_match::{pattern_match, Assignment};
use soniq::smol::problem1::{solve, Demand};
use soniq::smol::quant;
use soniq::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.range(lo, hi)).collect()
}

/// Reference dense conv on quantized values (f64 accumulate).
fn ref_conv(cfg: &ConvLayerCfg, x: &Tensor) -> Vec<f32> {
    let p = &cfg.plan;
    let (hout, wout) = (p.hout(), p.wout());
    let (pt, pl) = (p.pad_top(), p.pad_left());
    let mut out = vec![0f32; hout * wout * p.cout];
    for k in 0..p.cout {
        for h in 0..hout {
            for w in 0..wout {
                let mut acc = 0f64;
                for r in 0..p.kh {
                    for s in 0..p.kw {
                        let ih = h as isize * p.stride as isize + r as isize - pt;
                        let iw = w as isize * p.stride as isize + s as isize - pl;
                        if ih < 0 || iw < 0 || ih >= p.hin as isize || iw >= p.win as isize {
                            continue;
                        }
                        for c in 0..p.cin {
                            let prec = p.asg.precision[c];
                            let xv = quant::quantize(x.at(ih as usize, iw as usize, c), prec);
                            let wv = quant::quantize(
                                cfg.weights[((r * p.kw + s) * p.cin + c) * p.cout + k],
                                prec,
                            );
                            acc += (xv as f64) * (wv as f64);
                        }
                    }
                }
                out[(h * wout + w) * p.cout + k] = acc as f32;
            }
        }
    }
    out
}

/// Full pipeline: random per-channel s -> Problem 1 -> PatternMatch ->
/// pack -> Algorithm-4 codegen -> simulate -> must equal the reference
/// conv exactly, for every design point.
#[test]
fn end_to_end_mixed_precision_conv_all_design_points() {
    for np in [4usize, 8, 45] {
        let mut rng = Rng::new(42 + np as u64);
        let cin = 52usize;
        let s: Vec<f32> = (0..cin).map(|_| rng.range(-3.0, 6.0)).collect();
        let asg = pattern_match(&s, &design_subset(np));
        let plan = LayerPlan {
            name: format!("p{np}"),
            kind: LayerKind::Dense,
            cin,
            cout: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: 7,
            win: 7,
            asg,
            fmt: DataFormat::Smol,
        };
        let cfg = ConvLayerCfg {
            weights: rand_vec(&mut rng, 3 * 3 * cin * 6, -1.5, 1.5),
            plan,
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let x = Tensor { h: 7, w: 7, c: cin, data: rand_vec(&mut rng, 7 * 7 * cin, -2.0, 2.0) };
        let mut m = Machine::new();
        let (got, stats) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        assert_eq!(got.data, want, "np={np}");
        assert!(stats.cycles() > 0 && stats.energy_pj > 0.0);
    }
}

/// Lower precision must never simulate slower under the same shapes
/// (the Fig. 8 mechanism: fewer chunks = fewer vectors = fewer cycles).
#[test]
fn runtime_monotone_in_precision() {
    let mut cycles = Vec::new();
    for bits in [4u8, 2, 1] {
        let cin = 128usize;
        let plan = LayerPlan {
            name: format!("u{bits}"),
            kind: LayerKind::Dense,
            cin,
            cout: 16,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: 12,
            win: 12,
            asg: Assignment::uniform(cin, bits),
            fmt: DataFormat::Smol,
        };
        let mut rng = Rng::new(9);
        let cfg = ConvLayerCfg {
            weights: rand_vec(&mut rng, 3 * 3 * cin * 16, -1.0, 1.0),
            plan,
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let x = Tensor { h: 12, w: 12, c: cin, data: rand_vec(&mut rng, 12 * 12 * cin, -2.0, 2.0) };
        let mut m = Machine::new();
        let (_, stats) = run_conv(&mut m, &cfg, &x);
        cycles.push(stats.cycles());
    }
    assert!(cycles[0] > cycles[1], "U4 {} should be slower than U2 {}", cycles[0], cycles[1]);
    assert!(cycles[1] > cycles[2], "U2 {} should be slower than U1 {}", cycles[1], cycles[2]);
}

/// Problem 1 solutions for the paper's design subsets stay within one
/// vector of the P45 optimum on realistic demands (Key Finding 4's
/// "small number of patterns approximates the distribution well").
#[test]
fn p4_close_to_p45_on_realistic_demands() {
    let demands = [
        Demand { n1: 40, n2: 30, n4: 26 },
        Demand { n1: 90, n2: 20, n4: 18 },
        Demand { n1: 8, n2: 100, n4: 20 },
        Demand { n1: 0, n2: 0, n4: 96 },
        Demand { n1: 256, n2: 0, n4: 0 },
    ];
    for d in &demands {
        let best = solve(d, &all_patterns()).unwrap().num_vectors();
        let p4 = solve(d, &design_subset(4)).unwrap().num_vectors();
        assert!(p4 <= best + 1, "{d:?}: P4 {p4} vs P45 {best}");
    }
}

/// Graph building + full-network simulation from real artifacts (meta +
/// init state only; no PJRT needed). Checks output shape, determinism
/// and per-layer stat coverage for every model.
#[test]
fn netbuild_and_simulate_all_models_from_artifacts() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).join("tinynet.meta.json").exists() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    for model in ["tinynet", "resnet18", "mobilenetv2", "shufflenetv2"] {
        let meta_text = std::fs::read_to_string(format!("{dir}/{model}.meta.json")).unwrap();
        let meta = soniq::runtime::ModelMeta::parse(&meta_text).unwrap();
        let state =
            soniq::runtime::StateStore::load_init(&dir, &meta.init_bin, &meta.init_tensors)
                .unwrap();
        let asg: std::collections::HashMap<String, Assignment> = meta
            .layers
            .iter()
            .map(|l| (l.name.clone(), Assignment::uniform(l.cin, 4)))
            .collect();
        let graph =
            soniq::coordinator::netbuild::build_graph(&meta, &state, &asg, DataFormat::Smol)
                .unwrap();
        let img = meta.image;
        let mut rng = Rng::new(5);
        let input =
            Tensor { h: img, w: img, c: 3, data: rand_vec(&mut rng, img * img * 3, -1.0, 1.0) };
        let r1 = soniq::sim::network::run_network(&graph, &input);
        assert_eq!(r1.output.data.len(), meta.num_classes, "{model} logits");
        assert!(r1.output.data.iter().all(|v| v.is_finite()), "{model} finite");
        let n_convs = meta.layers.len();
        assert_eq!(r1.layers.len(), n_convs, "{model} per-layer stats");
        // determinism
        let r2 = soniq::sim::network::run_network(&graph, &input);
        assert_eq!(r1.output.data, r2.output.data, "{model} deterministic");
        assert_eq!(r1.total.cycles(), r2.total.cycles(), "{model} timing deterministic");
    }
}

/// Baseline formats order correctly on a channel-rich layer (Key
/// Finding 1's mechanism: U4 packs 32 channels per vector vs INT8's 16
/// and FP32's 4). Tiny stem layers (cin <= 16) cannot show this — the
/// Fig. 8 harness therefore times paper-scale shapes.
#[test]
fn baseline_format_ordering() {
    let cin = 128usize;
    let mut rng = Rng::new(11);
    let weights = rand_vec(&mut rng, 3 * 3 * cin * 32, -1.0, 1.0);
    let x = Tensor { h: 14, w: 14, c: cin, data: rand_vec(&mut rng, 14 * 14 * cin, -2.0, 2.0) };
    let mut cyc = std::collections::HashMap::new();
    for fmt in [DataFormat::Fp32, DataFormat::Int8, DataFormat::Smol] {
        let cfg = ConvLayerCfg {
            plan: LayerPlan {
                name: "wide".into(),
                kind: LayerKind::Dense,
                cin,
                cout: 32,
                kh: 3,
                kw: 3,
                stride: 1,
                hin: 14,
                win: 14,
                asg: Assignment::uniform(cin, 4),
                fmt,
            },
            weights: weights.clone(),
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let mut m = Machine::new();
        let (_, stats) = run_conv(&mut m, &cfg, &x);
        cyc.insert(format!("{fmt:?}"), stats.cycles());
    }
    assert!(cyc["Fp32"] > cyc["Int8"], "{cyc:?}");
    assert!(cyc["Int8"] > cyc["Smol"], "{cyc:?}");
    // U4 ~8x faster than FP32 on MAC-bound wide layers (paper: ~8x)
    let ratio = cyc["Fp32"] as f64 / cyc["Smol"] as f64;
    assert!(ratio > 3.0, "U4 speedup vs FP32 too small: {ratio:.2} ({cyc:?})");
}
