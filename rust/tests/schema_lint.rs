//! Schema-drift lint: the serve-report schema number is declared once
//! (`SERVE_REPORT_SCHEMA` in `src/serve/metrics.rs`) but *claimed* in
//! prose and CI greps. PR 8 shipped with DESIGN.md still describing the
//! report as schema 4 — this test makes that class of drift a failure.
//!
//! Checked claim forms (anything stating the *current* number):
//! - `"schema":N` — the JSON literal CI greps for;
//! - `schema-N` / `schema N` / `(schema N)` — prose shorthand;
//! - `currently N` on a line that mentions the schema.
//!
//! Changelog arrows (`schema bumped 3 → 4`) are deliberately exempt:
//! they describe history, not the current number, and stay correct
//! after future bumps.
//!
//! The same treatment applies to the static-analysis layer count: the
//! ground truth is the number of `pub mod` submodules in
//! `src/analysis/mod.rs`, and every "N layers" claim in that module's
//! doc and in DESIGN.md's "Static analysis" section must agree with it
//! (other sections describe unrelated layerings and are out of scope).

use std::fs;
use std::path::{Path, PathBuf};

fn repo_file(rel: &str) -> (PathBuf, String) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(rel);
    let text = fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
    (path, text)
}

fn declared_schema() -> u64 {
    let (path, src) = repo_file("src/serve/metrics.rs");
    let line = src
        .lines()
        .find(|l| l.contains("SERVE_REPORT_SCHEMA") && l.contains('='))
        .unwrap_or_else(|| panic!("no SERVE_REPORT_SCHEMA declaration in {}", path.display()));
    line.split('=')
        .nth(1)
        .and_then(|rhs| rhs.trim().trim_end_matches(';').trim().parse().ok())
        .unwrap_or_else(|| panic!("unparseable declaration: {line:?}"))
}

/// Every numbered current-schema claim in `text` as `(line, number)`.
fn schema_claims(text: &str) -> Vec<(usize, u64)> {
    let mut claims = Vec::new();
    let bytes = text.as_bytes();
    let mut search = 0;
    while let Some(found) = text[search..].find("schema") {
        let start = search + found;
        search = start + "schema".len();
        // a short run of separators between the word and a number:
        // `"schema":5`, `schema-5`, `schema 5`. Longer gaps (e.g.
        // `schema bumped 3 → 4`) are not direct claims.
        let mut i = search;
        let mut seps = 0;
        while i < bytes.len() && seps < 3 && matches!(bytes[i], b'"' | b':' | b'-' | b' ') {
            i += 1;
            seps += 1;
        }
        let digits_start = i;
        while i < bytes.len() && bytes[i].is_ascii_digit() {
            i += 1;
        }
        if i > digits_start {
            let line = text[..start].bytes().filter(|&b| b == b'\n').count() + 1;
            claims.push((line, text[digits_start..i].parse().unwrap()));
        }
    }
    // `currently N` on schema-mentioning lines ("the `schema` field,
    // currently 5, versions this")
    for (ln, line) in text.lines().enumerate() {
        if !line.contains("schema") {
            continue;
        }
        if let Some(pos) = line.find("currently ") {
            let rest = &line[pos + "currently ".len()..];
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if !digits.is_empty() {
                claims.push((ln + 1, digits.parse().unwrap()));
            }
        }
    }
    claims
}

#[test]
fn docs_and_ci_agree_with_serve_report_schema() {
    let want = declared_schema();
    let mut drift = Vec::new();
    let mut total = 0;
    for rel in ["../DESIGN.md", "../.github/workflows/ci.yml"] {
        let (path, text) = repo_file(rel);
        for (line, got) in schema_claims(&text) {
            total += 1;
            if got != want {
                drift.push(format!(
                    "{}:{line}: claims schema {got}, but SERVE_REPORT_SCHEMA = {want}",
                    path.display()
                ));
            }
        }
    }
    // the lint must actually be exercising something: CI greps the JSON
    // literal and DESIGN.md documents the field, so zero claims means
    // the scanner (or the docs) broke
    assert!(total >= 2, "only {total} schema claims found — scanner or docs broke");
    assert!(drift.is_empty(), "schema drift:\n{}", drift.join("\n"));
}

/// Number of analysis layers actually present: the `pub mod` lines of
/// `src/analysis/mod.rs`.
fn analysis_submodule_count() -> u64 {
    let (path, src) = repo_file("src/analysis/mod.rs");
    let count = src.lines().filter(|l| l.trim_start().starts_with("pub mod ")).count() as u64;
    assert!(count > 0, "no pub mod lines in {}", path.display());
    count
}

/// Every "N layers" claim in `text` as `(line, number)`, accepting the
/// digit form (`3 layers`) and spelled-out counts up to ten (`three
/// layers`, `Three layers`). Lines like "the kernel layer" or "both
/// layers" carry no number and are not claims.
fn layer_claims(text: &str) -> Vec<(usize, u64)> {
    const WORDS: [&str; 10] =
        ["one", "two", "three", "four", "five", "six", "seven", "eight", "nine", "ten"];
    let mut claims = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let mut search = 0;
        while let Some(found) = line[search..].find("layers") {
            let start = search + found;
            search = start + "layers".len();
            let Some(prev) = line[..start].split_whitespace().last() else { continue };
            let prev = prev.trim_matches(|c: char| !c.is_ascii_alphanumeric());
            let n = if prev.bytes().all(|b| b.is_ascii_digit()) && !prev.is_empty() {
                prev.parse().ok()
            } else {
                WORDS
                    .iter()
                    .position(|w| prev.eq_ignore_ascii_case(w))
                    .map(|i| i as u64 + 1)
            };
            if let Some(n) = n {
                claims.push((ln + 1, n));
            }
        }
    }
    claims
}

/// The body of DESIGN.md's "Static analysis" section: from its `## `
/// heading to the next `## ` heading (or end of file).
fn static_analysis_section(design: &str) -> (usize, String) {
    let mut lines = Vec::new();
    let mut start = 0;
    let mut inside = false;
    for (ln, line) in design.lines().enumerate() {
        if line.starts_with("## ") {
            if inside {
                break;
            }
            if line.contains("Static analysis") {
                inside = true;
                start = ln + 1;
            }
        }
        if inside {
            lines.push(line);
        }
    }
    assert!(inside, "DESIGN.md has no \"Static analysis\" section");
    (start, lines.join("\n"))
}

#[test]
fn layer_count_claims_match_analysis_submodules() {
    let want = analysis_submodule_count();
    let mut drift = Vec::new();
    let mut total = 0;

    // the analysis module doc (`//!` lines only — code comments about
    // e.g. register lattices are not layer-count claims)
    let (path, src) = repo_file("src/analysis/mod.rs");
    let doc: String = src
        .lines()
        .take_while(|l| l.starts_with("//!") || l.is_empty())
        .collect::<Vec<_>>()
        .join("\n");
    for (line, got) in layer_claims(&doc) {
        total += 1;
        if got != want {
            drift.push(format!(
                "{}:{line}: claims {got} layers, but analysis has {want} submodules",
                path.display()
            ));
        }
    }

    // DESIGN.md, scoped to the "Static analysis" section
    let (path, design) = repo_file("../DESIGN.md");
    let (offset, section) = static_analysis_section(&design);
    for (line, got) in layer_claims(&section) {
        total += 1;
        if got != want {
            drift.push(format!(
                "{}:{}: claims {got} layers, but analysis has {want} submodules",
                path.display(),
                offset + line - 1
            ));
        }
    }

    // both the module doc and DESIGN.md state the count today; zero
    // claims means the scanner (or the docs) broke
    assert!(total >= 2, "only {total} layer-count claims found — scanner or docs broke");
    assert!(drift.is_empty(), "layer-count drift:\n{}", drift.join("\n"));
}

#[test]
fn layer_scanner_understands_the_known_forms() {
    let text = "Three layers (see DESIGN.md):\norganized as three layers: a safety\n\
                the kernel layer proves safety\nboth kernel-level layers run there\n\
                split into 3 layers\n";
    let claims = layer_claims(text);
    assert_eq!(claims, vec![(1, 3), (2, 3), (5, 3)]);
}

#[test]
fn claim_scanner_understands_the_known_forms() {
    let text = "grep '\"schema\":7'\na schema-7 report\n(schema 7)\n\
                the `schema` field, currently 7, versions this\n\
                (schema bumped 6 \u{2192} 7 together with X)\n";
    let claims = schema_claims(text);
    assert_eq!(claims.iter().map(|&(_, n)| n).collect::<Vec<_>>(), vec![7, 7, 7, 7]);
    assert_eq!(claims[0].0, 1);
    assert_eq!(claims[3].0, 4);
}
