//! Integration tests over the PJRT runtime: load the AOT artifacts, run
//! train/eval steps, and cross-validate the rust SIMD simulator against
//! the JAX/Pallas eval path end to end.
//!
//! Requires `make artifacts` (skipped with a message otherwise) and a
//! build with the `pjrt` feature (the whole file is compiled out without
//! it — the executor stub cannot run steps).
#![cfg(feature = "pjrt")]

use soniq::coordinator::netbuild;
use soniq::data::Dataset;
use soniq::runtime::{HostTensor, Runtime};
use soniq::sim::network::{run_network, Tensor};
use soniq::smol::pattern_match::Assignment;
use soniq::smol::quant;
use soniq::train::{uniform_prec, Trainer};
use std::collections::HashMap;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("tinynet.meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        None
    }
}

#[test]
fn kernel_qmm_artifact_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let proto =
        xla::HloModuleProto::from_text_file(&format!("{dir}/kernel_qmm.hlo.txt")).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();

    let (m, k, n) = (32usize, 64usize, 16usize);
    let mut rng = soniq::util::rng::Rng::new(7);
    let x: Vec<f32> = (0..m * k).map(|_| rng.range(-3.0, 3.0)).collect();
    let prec: Vec<u8> = (0..k).map(|_| *rng.choice(&[1u8, 2, 4])).collect();
    let step: Vec<f32> = prec.iter().map(|&p| quant::step_for(p)).collect();
    let qmax: Vec<f32> = prec.iter().map(|&p| quant::qmax_for(p)).collect();
    let wq: Vec<f32> = (0..k * n)
        .map(|i| quant::quantize(rng.range(-2.0, 2.0), prec[i / n]))
        .collect();

    let lx = xla::Literal::vec1(&x).reshape(&[m as i64, k as i64]).unwrap();
    let lw = xla::Literal::vec1(&wq).reshape(&[k as i64, n as i64]).unwrap();
    let ls = xla::Literal::vec1(&step);
    let lq = xla::Literal::vec1(&qmax);
    let out = exe.execute::<xla::Literal>(&[lx, lw, ls, lq]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let got = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();

    // rust reference: quantize activations per channel, exact dot
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for c in 0..k {
                let xq = quant::quantize(x[i * k + c], prec[c]);
                acc += (xq as f64) * (wq[c * n + j] as f64);
            }
            assert_eq!(got[i * n + j], acc as f32, "({i},{j})");
        }
    }
}

#[test]
fn tinynet_training_steps_run_and_learn() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir, "tinynet", Some(&["fp32_step", "eval_fp32"])).unwrap();
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut tr = Trainer::new(&rt, &dataset).unwrap();
    let (first_loss, _) = tr.fp32_step(0, 0.05).unwrap();
    assert!(first_loss.is_finite() && first_loss > 0.0);
    for i in 1..30 {
        tr.fp32_step(i, 0.05).unwrap();
    }
    let last = tr.history.last().unwrap().loss;
    assert!(last < first_loss, "loss should decrease: {first_loss} -> {last}");
    let acc = tr.eval(None, 2).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(acc > 0.15, "fp32 accuracy after 30 steps should beat chance: {acc}");
}

#[test]
fn tinynet_phase1_phase2_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let rt =
        Runtime::load(&dir, "tinynet", Some(&["phase1_step", "phase2_step", "eval_quant"]))
            .unwrap();
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut tr = Trainer::new(&rt, &dataset).unwrap();
    for i in 0..5 {
        let (loss, _) = tr.phase1_step(i, 0.05, 1e-7).unwrap();
        assert!(loss.is_finite());
    }
    // s vectors must exist for every layer and be finite
    let s = tr.state.s_vectors();
    for l in &rt.meta.layers {
        let v = &s[&l.name];
        assert_eq!(v.len(), l.cin);
        assert!(v.iter().all(|x| x.is_finite()));
    }
    let prec = uniform_prec(&rt.meta.layers, 4);
    for i in 0..5 {
        let (loss, _) = tr.phase2_step(5 + i, &prec, 0.05).unwrap();
        assert!(loss.is_finite());
    }
    let acc = tr.eval(Some(&prec), 1).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

/// The big cross-layer check: the rust SIMD simulator's functional output
/// must track the JAX/Pallas eval artifact on the same trained weights.
/// BN epilogues run in f32 on both sides with different op orders, so we
/// compare logit closeness + prediction agreement rather than bit
/// equality (the MAC datapaths themselves are proven bit-exact at the
/// kernel level in python/tests and in the rust unit tests).
#[test]
fn simulator_tracks_pjrt_eval_on_tinynet_u4() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir, "tinynet", Some(&["phase2_step", "eval_quant"])).unwrap();
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut tr = Trainer::new(&rt, &dataset).unwrap();
    let prec = uniform_prec(&rt.meta.layers, 4);
    for i in 0..20 {
        tr.phase2_step(i, &prec, 0.05).unwrap();
    }

    // PJRT logits on an eval batch
    let img = rt.meta.image;
    let eb = rt.meta.eval_batch;
    let b = dataset.batch(1, 0, eb);
    let images = HostTensor::f32(vec![eb, img, img, 3], b.images.clone());
    let pjrt_logits = tr.eval_logits(Some(&prec), &images).unwrap();

    // simulator logits, image by image
    let asg: HashMap<String, Assignment> = rt
        .meta
        .layers
        .iter()
        .map(|l| (l.name.clone(), Assignment::uniform(l.cin, 4)))
        .collect();
    let graph = netbuild::build_graph(
        &rt.meta,
        &tr.state,
        &asg,
        soniq::codegen::DataFormat::Smol,
    )
    .unwrap();
    let classes = rt.meta.num_classes;
    let mut agree = 0usize;
    let n_check = 8usize;
    for i in 0..n_check {
        let data = b.images[i * img * img * 3..(i + 1) * img * img * 3].to_vec();
        let input = Tensor { h: img, w: img, c: 3, data };
        let net = run_network(&graph, &input);
        let sim_row = &net.output.data;
        let pjrt_row = &pjrt_logits[i * classes..(i + 1) * classes];
        let max_diff = sim_row
            .iter()
            .zip(pjrt_row)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 0.05, "image {i}: sim vs pjrt logit diff {max_diff}");
        let argmax = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        if argmax(sim_row) == argmax(pjrt_row) {
            agree += 1;
        }
    }
    assert_eq!(agree, n_check, "sim and PJRT must agree on predictions");
}
