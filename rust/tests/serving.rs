//! Integration tests for the `serve` subsystem: (a) prepared-model
//! outputs are bit-identical to the one-shot `run_network` path, (b)
//! the session-affine dynamic batcher groups by target and closes on
//! the max-batch / latency-deadline / FIFO rules, (c) concurrent
//! workers produce deterministic per-request results, (d) KV-cached
//! decode steps are bit-identical to prefix re-runs and cost fewer
//! simulated cycles — plus registry and report checks.

use soniq::coordinator::{
    synthetic_inputs, synthetic_network, synthetic_network_seq, synthetic_step_inputs,
    DesignPoint, SyntheticNet,
};
use soniq::serve::{
    serve_all, summarize, BatchConfig, DynamicBatcher, EngineMachine, ModelKey, ModelRegistry,
    PreparedModel, Request, ServeConfig, Server, SessionId, SetupTiming,
};
use soniq::sim::network::{run_network, Tensor};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn net_and_inputs(model: &str, dp: DesignPoint, n: usize) -> (SyntheticNet, Vec<Tensor>) {
    let net = synthetic_network(model, dp, 3).unwrap();
    let inputs = synthetic_inputs(&net, n, 5);
    (net, inputs)
}

#[test]
fn prepared_model_matches_legacy_bit_exact() {
    for (model, dp) in [
        ("tinynet", DesignPoint::Patterns(4)),
        ("tinynet", DesignPoint::Uniform(2)),
        ("tinydw", DesignPoint::Patterns(8)),
        ("tinyattn", DesignPoint::Patterns(4)),
        ("tinyattn", DesignPoint::Uniform(2)),
        ("tinydec", DesignPoint::Patterns(4)),
    ] {
        let (net, inputs) = net_and_inputs(model, dp, 4);
        let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
        let mut engine = EngineMachine::new(&prepared);
        for (i, x) in inputs.iter().enumerate() {
            let legacy = run_network(&net.nodes, x);
            let served = engine.run(x);
            assert_eq!(
                served.output.data,
                legacy.output.data,
                "{model}/{} request {i}",
                dp.label()
            );
            assert!(served.output.data.iter().all(|v| v.is_finite()));
            assert_eq!(served.layers.len(), legacy.layers.len());
        }
    }
}

#[test]
fn streaming_and_prepared_paths_are_bit_identical_per_layer() {
    // run_conv (streaming emission, O(1) memory) vs prepare/bind/run
    // through the PreparedOp trait: same staging + epilogue, same alloc
    // order -> outputs AND stats must match exactly on fresh machines
    use soniq::serve::{ExecCtx, PreparedConv, PreparedOp, WorkerScratch};
    use soniq::sim::machine::Machine;
    use soniq::sim::network::{run_conv, Node};
    let (net, inputs) = net_and_inputs("tinydw", DesignPoint::Patterns(4), 1);
    for node in &net.nodes {
        if let Node::Conv { cfg, .. } = node {
            let shaped = Tensor {
                h: cfg.plan.hin,
                w: cfg.plan.win,
                c: cfg.plan.cin,
                data: (0..cfg.plan.hin * cfg.plan.win * cfg.plan.cin)
                    .map(|i| inputs[0].data[i % inputs[0].data.len()] * 0.7)
                    .collect(),
            };
            let mut m1 = Machine::new();
            let (out1, stats1) = run_conv(&mut m1, cfg, &shaped);
            let mut m2 = Machine::new();
            let prep = PreparedConv::prepare(cfg);
            let bound = prep.bind(&mut m2).expect("conv binds");
            let mut scratch = WorkerScratch::default();
            let mut ctx = ExecCtx {
                m: &mut m2,
                bound: Some(&bound),
                scratch: &mut scratch,
                session: None,
            };
            let out2 = prep.run(&mut ctx, &[&shaped]);
            let stats2 = m2.take_stats();
            assert_eq!(out1.data, out2.data, "layer {}", cfg.plan.name);
            assert_eq!(stats1.instrs, stats2.instrs, "layer {}", cfg.plan.name);
            assert_eq!(stats1.cycles(), stats2.cycles(), "layer {}", cfg.plan.name);
        }
    }
}

#[test]
fn first_request_stats_match_one_shot_path() {
    // a fresh engine's first request must cost exactly what the one-shot
    // path reports (same buffers, same cold caches, same kernel)
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 1);
    let legacy = run_network(&net.nodes, &inputs[0]);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut engine = EngineMachine::new(&prepared);
    let served = engine.run(&inputs[0]);
    assert_eq!(served.total.instrs, legacy.total.instrs);
    assert_eq!(served.total.cycles(), legacy.total.cycles());
    assert_eq!(served.total.energy_pj, legacy.total.energy_pj);
}

#[test]
fn batcher_closes_on_max_batch() {
    let cfg = BatchConfig { max_batch: 4, max_delay: Duration::from_secs(3600) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let mk = |id| Request::infer(id, Tensor::zeros(1, 1, 1), t0);
    assert!(b.push(mk(0)).is_none());
    assert!(b.push(mk(1)).is_none());
    assert!(b.push(mk(2)).is_none());
    let batch = b.push(mk(3)).expect("size trigger closes the batch");
    assert_eq!(batch.requests.len(), 4);
    assert_eq!(batch.target, None);
    let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3]);
    assert!(b.is_empty());
    // with an hour of delay budget the deadline never fires
    assert!(b.poll_deadline(Instant::now()).is_none());
}

#[test]
fn batcher_closes_on_deadline() {
    let cfg = BatchConfig { max_batch: 1000, max_delay: Duration::from_millis(5) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let mk = |id| Request::infer(id, Tensor::zeros(1, 1, 1), t0);
    assert!(b.push(mk(0)).is_none());
    assert!(b.push(mk(1)).is_none());
    assert_eq!(b.len(), 2);
    // just before the oldest request's deadline: stays open
    assert!(b.poll_deadline(t0 + Duration::from_millis(4)).is_none());
    // at the deadline: closes with everything pending
    let batch = b.poll_deadline(t0 + Duration::from_millis(5)).expect("deadline trigger");
    assert_eq!(batch.requests.len(), 2);
    assert!(b.next_deadline().is_none());
    // flush drains leftovers on shutdown (and is a no-op when empty)
    assert!(b.flush().is_none());
    assert!(b.push(mk(2)).is_none());
    assert_eq!(b.flush().unwrap().requests.len(), 1);
}

#[test]
fn batcher_groups_by_target_and_closes_fifo() {
    let cfg = BatchConfig { max_batch: 8, max_delay: Duration::from_millis(5) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let tok = || Tensor::zeros(1, 1, 1);
    // interleaved arrival: infer, step->w0, infer, step->w1, step->w0
    assert!(b.push(Request::infer(0, tok(), t0)).is_none());
    assert!(b.push(Request::step(1, 7, tok(), 0, t0 + Duration::from_micros(1))).is_none());
    assert!(b.push(Request::infer(2, tok(), t0 + Duration::from_micros(2))).is_none());
    assert!(b.push(Request::step(3, 8, tok(), 1, t0 + Duration::from_micros(3))).is_none());
    assert!(b.push(Request::step(4, 10, tok(), 0, t0 + Duration::from_micros(4))).is_none());
    assert_eq!(b.len(), 5);
    // deadline closes groups FIFO by their oldest request: shared {0,2},
    // then worker-0 {1,4} (same-step sessions batch together), then
    // worker-1 {3} — encode and decode traffic cannot starve each other
    let now = t0 + Duration::from_millis(10);
    let g1 = b.poll_deadline(now).expect("shared group first");
    assert_eq!(g1.target, None);
    assert_eq!(g1.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    let g2 = b.poll_deadline(now).expect("worker-0 group second");
    assert_eq!(g2.target, Some(0));
    assert_eq!(g2.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 4]);
    let g3 = b.poll_deadline(now).expect("worker-1 group last");
    assert_eq!(g3.target, Some(1));
    assert_eq!(g3.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
    assert!(b.poll_deadline(now).is_none());
    assert!(b.is_empty());

    // the size trigger closes only the full group; others keep waiting
    let mut b = DynamicBatcher::new(BatchConfig {
        max_batch: 2,
        max_delay: Duration::from_secs(3600),
    });
    assert!(b.push(Request::infer(0, tok(), t0)).is_none());
    assert!(b.push(Request::step(1, 0, tok(), 1, t0)).is_none());
    let full = b.push(Request::step(2, 1, tok(), 1, t0)).expect("size trigger");
    assert_eq!(full.target, Some(1));
    assert_eq!(full.requests.len(), 2);
    assert_eq!(b.len(), 1);
    assert_eq!(b.flush().unwrap().requests[0].id, 0);
}

#[test]
fn batcher_edge_cases() {
    let mk = |id, t| Request::infer(id, Tensor::zeros(1, 1, 1), t);

    // flush on a never-used empty batcher is a no-op (the dispatcher's
    // shutdown drain loop relies on it)
    let mut b = DynamicBatcher::new(BatchConfig {
        max_batch: 8,
        max_delay: Duration::from_millis(5),
    });
    assert!(b.flush().is_none());
    assert!(b.next_deadline().is_none());

    // the deadline trigger fires at the exact deadline instant (>=, not >)
    let t0 = Instant::now();
    assert!(b.push(mk(0, t0)).is_none());
    let deadline = b.next_deadline().expect("deadline while pending");
    assert_eq!(deadline, t0 + Duration::from_millis(5));
    assert!(b.poll_deadline(deadline - Duration::from_nanos(1)).is_none());
    let batch = b.poll_deadline(deadline).expect("exact-instant close");
    assert_eq!(batch.requests.len(), 1);
    assert!(b.is_empty());

    // max_batch = 0 normalizes to 1: every push closes as its own batch
    let mut b1 = DynamicBatcher::new(BatchConfig {
        max_batch: 0,
        max_delay: Duration::from_secs(3600),
    });
    for id in 0..3u64 {
        let batch = b1.push(mk(id, Instant::now())).expect("size trigger on every push");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.requests[0].id, id);
        assert!(b1.is_empty());
        assert!(b1.next_deadline().is_none());
    }
}

#[test]
fn closed_sessions_free_their_caches_and_restart_empty() {
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    // engine level: end_session drops the KV state, and reusing the id
    // starts from position 0 (bit-identical to the original first step)
    let mut engine = EngineMachine::new(&prepared);
    let tokens = synthetic_step_inputs(&net, 0, 3, 17);
    let first = engine.run_step(5, &tokens[0]);
    engine.run_step(5, &tokens[1]);
    assert_eq!(engine.num_sessions(), 1);
    engine.end_session(5);
    assert_eq!(engine.num_sessions(), 0);
    let restarted = engine.run_step(5, &tokens[0]);
    assert_eq!(first.output.data, restarted.output.data);
    engine.end_session(99); // unknown id: no-op

    // server level: close rides the session FIFO, so all prior steps
    // still complete with their outputs intact
    let cfg = ServeConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 4, max_delay: Duration::from_millis(1) },
    };
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let sid = server.open_session();
    for tok in &tokens {
        server.submit_step(sid, tok.clone());
    }
    server.close_session(sid);
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), tokens.len()); // close produces no completion
    assert_eq!(done[0].output.data, first.output.data);
}

#[test]
fn concurrent_workers_are_deterministic_and_bit_exact() {
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 24);
    let legacy: Vec<Vec<f32>> =
        inputs.iter().map(|x| run_network(&net.nodes, x).output.data.clone()).collect();
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let cfg = ServeConfig {
        workers: 3,
        batch: BatchConfig { max_batch: 4, max_delay: Duration::from_millis(1) },
    };
    let run1 = serve_all(&prepared, &cfg, inputs.clone());
    assert_eq!(run1.len(), inputs.len());
    for c in &run1 {
        assert_eq!(c.output.data, legacy[c.id as usize], "request {}", c.id);
        assert!(c.batch_size >= 1 && c.batch_size <= 4);
        assert!(c.worker < 3);
        assert_eq!(c.session, None);
    }
    // a second serving run over the same prepared model reproduces every
    // output exactly, regardless of worker/batch scheduling
    let run2 = serve_all(&prepared, &cfg, inputs.clone());
    assert_eq!(run1.len(), run2.len());
    for (a, b) in run1.iter().zip(&run2) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output.data, b.output.data, "request {}", a.id);
    }
}

#[test]
fn tinyattn_prepared_matches_one_shot_under_4_workers() {
    let (net, inputs) = net_and_inputs("tinyattn", DesignPoint::Patterns(4), 16);
    let legacy: Vec<Vec<f32>> =
        inputs.iter().map(|x| run_network(&net.nodes, x).output.data.clone()).collect();
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    // 2 blocks x (wq, wk, wv, qk, av, wo, ff1, ff2) prepared kernels
    assert_eq!(prepared.num_layers(), 16);
    for max_batch in [1usize, 4] {
        let cfg = ServeConfig {
            workers: 4,
            batch: BatchConfig { max_batch, max_delay: Duration::from_millis(1) },
        };
        let done = serve_all(&prepared, &cfg, inputs.clone());
        assert_eq!(done.len(), inputs.len());
        for c in &done {
            assert_eq!(
                c.output.data,
                legacy[c.id as usize],
                "request {} (max_batch {max_batch})",
                c.id
            );
            assert!(c.output.data.iter().all(|v| v.is_finite()));
            assert_eq!(c.per_layer.len(), 16);
        }
    }
}

#[test]
fn tinyattn_dynamic_operands_deterministic_across_placement() {
    // QK^T / A·V pack their "weight" operand per request into per-worker
    // scratch — the same request must produce bit-identical results no
    // matter which worker or batch slot it lands in, and no matter how
    // warm the worker's machine already is.
    let (net, inputs) = net_and_inputs("tinyattn", DesignPoint::Patterns(8), 1);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut engine = EngineMachine::new(&prepared);
    let reference = engine.run(&inputs[0]);
    let again = engine.run(&inputs[0]); // warm machine, same request
    assert_eq!(reference.output.data, again.output.data);
    assert_eq!(reference.total.instrs, again.total.instrs);

    let cfg = ServeConfig {
        workers: 4,
        batch: BatchConfig { max_batch: 3, max_delay: Duration::from_millis(1) },
    };
    let copies = vec![inputs[0].clone(); 12];
    let done = serve_all(&prepared, &cfg, copies);
    assert_eq!(done.len(), 12);
    for c in &done {
        assert_eq!(
            c.output.data, reference.output.data,
            "request {} on worker {} batch {}",
            c.id, c.worker, c.batch_id
        );
    }
}

#[test]
fn cached_decode_matches_prefix_rerun_and_costs_fewer_cycles() {
    // the tentpole contract: every cached decode step is bit-identical
    // to re-running its full prefix through the one-shot causal graph,
    // at a fraction of the simulated cycles
    let dp = DesignPoint::Patterns(8);
    let net = synthetic_network("tinydec", dp, 5).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    let mut engine = EngineMachine::new(&prepared);
    let steps = 6usize;
    let tokens = synthetic_step_inputs(&net, 0, steps, 13);
    let mut cached_cycles = 0u64;
    let mut baseline_cycles = 0u64;
    for t in 0..steps {
        let step_res = engine.run_step(42, &tokens[t]);
        cached_cycles += step_res.total.cycles();
        let net_t = synthetic_network_seq("tinydec", dp, 5, Some(t + 1)).unwrap();
        let (h, w, c) = net_t.input_shape;
        let mut data = Vec::new();
        for tok in tokens.iter().take(t + 1) {
            data.extend_from_slice(&tok.data);
        }
        let full = run_network(&net_t.nodes, &Tensor { h, w, c, data });
        baseline_cycles += full.total.cycles();
        assert_eq!(
            step_res.output.data[..],
            full.output.data[t * c..(t + 1) * c],
            "decode step {t} != one-shot prefix row"
        );
        assert!(step_res.output.data.iter().all(|v| v.is_finite()));
    }
    assert_eq!(engine.num_sessions(), 1);
    assert!(
        cached_cycles < baseline_cycles,
        "cached decode ({cached_cycles} cycles) must beat prefix repack ({baseline_cycles})"
    );
}

#[test]
fn decode_sessions_stay_on_their_pinned_worker() {
    // session affinity: every step of a session lands on the worker
    // that owns its KV cache, across many interleaved sessions
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    let cfg = ServeConfig {
        workers: 3,
        batch: BatchConfig { max_batch: 4, max_delay: Duration::from_millis(1) },
    };
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let sids: Vec<SessionId> = (0..6).map(|_| server.open_session()).collect();
    let steps = 5usize;
    let tokens: Vec<Vec<Tensor>> =
        (0..6).map(|k| synthetic_step_inputs(&net, k, steps, 9)).collect();
    for t in 0..steps {
        for (si, sid) in sids.iter().enumerate() {
            server.submit_step(*sid, tokens[si][t].clone());
        }
    }
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 6 * steps);
    let mut worker_of: HashMap<u64, usize> = HashMap::new();
    let mut steps_of: HashMap<u64, usize> = HashMap::new();
    for c in &done {
        let sid = c.session.expect("decode completion carries its session");
        *steps_of.entry(sid).or_insert(0) += 1;
        match worker_of.get(&sid) {
            Some(&w) => assert_eq!(w, c.worker, "session {sid} split across workers"),
            None => {
                worker_of.insert(sid, c.worker);
            }
        }
    }
    assert_eq!(worker_of.len(), 6);
    for (sid, w) in &worker_of {
        assert_eq!(*w, (*sid as usize) % 3, "session {sid} not on its pinned worker");
    }
    assert!(steps_of.values().all(|&n| n == steps));

    // deterministic: the served outputs match a single-engine replay
    let mut engine = EngineMachine::new(&prepared);
    for c in &done {
        if c.session == Some(sids[0].0) {
            let t = (c.id as usize) / sids.len(); // step-major submission
            let want = engine.run_step(999, &tokens[0][t]);
            assert_eq!(c.output.data, want.output.data, "session 0 step {t}");
        }
    }
}

#[test]
fn transpose_hw_swaps_axes_and_roundtrips() {
    use soniq::sim::network::{Node, INPUT};
    let t = Tensor { h: 3, w: 5, c: 2, data: (0..30).map(|i| i as f32).collect() };
    let once = run_network(&[Node::TransposeHW { x: INPUT }], &t);
    assert_eq!((once.output.h, once.output.w, once.output.c), (5, 3, 2));
    for h in 0..3 {
        for w in 0..5 {
            for c in 0..2 {
                assert_eq!(once.output.at(w, h, c), t.at(h, w, c), "h{h} w{w} c{c}");
            }
        }
    }
    // transposing twice is the identity
    let twice = run_network(&[Node::TransposeHW { x: INPUT }, Node::TransposeHW { x: 0 }], &t);
    assert_eq!(twice.output.data, t.data);
}

#[test]
fn registry_prepares_once_per_key() {
    let (net, _) = net_and_inputs("tinynet", DesignPoint::Uniform(4), 1);
    let reg = ModelRegistry::new();
    let key = ModelKey::new("tinynet", "U4");
    assert_eq!(key.to_string(), "tinynet/U4");
    assert!(!reg.contains(&key));
    let mut builds = 0u32;
    let a = reg.get_or_prepare(&key, || {
        builds += 1;
        PreparedModel::prepare(&net.nodes)
    });
    let b = reg.get_or_prepare(&key, || {
        builds += 1;
        PreparedModel::prepare(&net.nodes)
    });
    assert!(Arc::ptr_eq(&a, &b));
    assert_eq!(builds, 1, "model must be prepared exactly once per key");
    assert!(reg.contains(&key));
    assert_eq!(reg.len(), 1);
    assert_eq!(a.num_layers(), 4);
}

#[test]
fn serve_report_aggregates_and_serializes() {
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Uniform(4), 12);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let cfg = ServeConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 4, max_delay: Duration::from_millis(1) },
    };
    let t0 = Instant::now();
    let done = serve_all(&prepared, &cfg, inputs);
    let setup = SetupTiming {
        prepare: Duration::from_millis(3),
        bind: Duration::from_micros(500),
    };
    let report = summarize(&done, t0.elapsed(), setup);
    assert_eq!(report.requests, 12);
    assert!(report.batches >= 3 && report.batches <= 12, "batches {}", report.batches);
    assert!(report.mean_batch_size >= 1.0 && report.mean_batch_size <= 4.0);
    assert!(report.throughput_rps > 0.0);
    // steady-state excludes bind time, so it can only be faster
    assert!(report.steady_rps >= report.throughput_rps);
    assert_eq!(report.setup.prepare, Duration::from_millis(3));
    assert!(report.p50_ms <= report.p99_ms);
    assert!(report.sim.cycles() > 0 && report.sim.energy_pj > 0.0);
    // one aggregate per conv/FC layer: c1, c2, c3, fc
    assert_eq!(report.per_layer.len(), 4);
    assert!(report.per_layer.iter().all(|l| l.cycles > 0));
    // JSON round-trips through the offline parser
    let text = report.to_json().to_string();
    let parsed = soniq::util::json::parse(&text).unwrap();
    assert_eq!(parsed.get("requests").unwrap().as_usize().unwrap(), 12);
    assert_eq!(parsed.get("per_layer").unwrap().as_arr().unwrap().len(), 4);
    assert!(parsed.get("prepare_ms").is_some());
    assert!(parsed.get("bind_ms").is_some());
    assert!(parsed.get("steady_throughput_rps").is_some());
}
