//! Integration tests for the `serve` subsystem: (a) prepared-model
//! outputs are bit-identical to the one-shot `run_network` path, (b)
//! the model/session-affine dynamic batcher groups by `(model, target)`
//! and closes on the max-batch / latency-deadline / FIFO rules, (c)
//! concurrent workers produce deterministic per-request results, (d)
//! KV-cached decode steps are bit-identical to prefix re-runs and cost
//! fewer simulated cycles, (e) one worker pool serves several models —
//! bit-identical to dedicated single-model pools, through LRU
//! bind-table eviction and footprint-based session placement — plus
//! registry, lifecycle-guard and report checks.

use soniq::coordinator::{
    synthetic_inputs, synthetic_network, synthetic_network_seq, synthetic_step_inputs,
    DesignPoint, SyntheticNet,
};
use soniq::serve::{
    serve_all, summarize, summarize_with, BatchConfig, Completion, DeployConfig, Deployment,
    DynamicBatcher, EngineMachine, GatherMode, ModelHandle, ModelKey, ModelRegistry, PreparedModel,
    Request, ServeConfig, Server, SessionId, SetupTiming, ShardPlan, SERVE_REPORT_SCHEMA,
};
use soniq::sim::machine::RunStats;
use soniq::sim::network::{run_network, LayerStat, Node, Tensor};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn net_and_inputs(model: &str, dp: DesignPoint, n: usize) -> (SyntheticNet, Vec<Tensor>) {
    let net = synthetic_network(model, dp, 3).unwrap();
    let inputs = synthetic_inputs(&net, n, 5);
    (net, inputs)
}

fn pool_cfg(workers: usize, max_batch: usize) -> ServeConfig {
    ServeConfig {
        workers,
        batch: BatchConfig { max_batch, max_delay: Duration::from_millis(1) },
        ..ServeConfig::default()
    }
}

/// Prepare a synthetic model the way the registry would (decoder form
/// whenever the model has a step graph).
fn prepare_any(net: &SyntheticNet) -> Arc<PreparedModel> {
    Arc::new(net.prepare())
}

/// A handle for batcher-only tests (the model is never executed).
fn dummy_handle(name: &str) -> ModelHandle {
    ModelHandle::new(ModelKey::new(name, "P4"), Arc::new(PreparedModel::prepare(&[])))
}

#[test]
fn prepared_model_matches_legacy_bit_exact() {
    for (model, dp) in [
        ("tinynet", DesignPoint::Patterns(4)),
        ("tinynet", DesignPoint::Uniform(2)),
        ("tinydw", DesignPoint::Patterns(8)),
        ("tinyattn", DesignPoint::Patterns(4)),
        ("tinyattn", DesignPoint::Uniform(2)),
        ("tinydec", DesignPoint::Patterns(4)),
    ] {
        let (net, inputs) = net_and_inputs(model, dp, 4);
        let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
        let mut engine = EngineMachine::new(&prepared);
        for (i, x) in inputs.iter().enumerate() {
            let legacy = run_network(&net.nodes, x);
            let served = engine.run(x);
            assert_eq!(
                served.output.data,
                legacy.output.data,
                "{model}/{} request {i}",
                dp.label()
            );
            assert!(served.output.data.iter().all(|v| v.is_finite()));
            assert_eq!(served.layers.len(), legacy.layers.len());
        }
    }
}

#[test]
fn streaming_and_prepared_paths_are_bit_identical_per_layer() {
    // run_conv (streaming emission, O(1) memory) vs prepare/bind/run
    // through the PreparedOp trait: same staging + epilogue, same alloc
    // order -> outputs AND stats must match exactly on fresh machines
    use soniq::serve::{ExecCtx, PreparedConv, PreparedOp, WorkerScratch};
    use soniq::sim::machine::Machine;
    use soniq::sim::network::{run_conv, Node};
    let (net, inputs) = net_and_inputs("tinydw", DesignPoint::Patterns(4), 1);
    for node in &net.nodes {
        if let Node::Conv { cfg, .. } = node {
            let shaped = Tensor {
                h: cfg.plan.hin,
                w: cfg.plan.win,
                c: cfg.plan.cin,
                data: (0..cfg.plan.hin * cfg.plan.win * cfg.plan.cin)
                    .map(|i| inputs[0].data[i % inputs[0].data.len()] * 0.7)
                    .collect(),
            };
            let mut m1 = Machine::new();
            let (out1, stats1) = run_conv(&mut m1, cfg, &shaped);
            let mut m2 = Machine::new();
            let prep = PreparedConv::prepare(cfg);
            let bound = prep.bind(&mut m2).expect("conv binds");
            let mut scratch = WorkerScratch::default();
            let mut ctx = ExecCtx {
                m: &mut m2,
                bound: Some(&bound),
                scratch: &mut scratch,
                session: None,
                kv: None,
            };
            let out2 = prep.run(&mut ctx, &[&shaped]);
            let stats2 = m2.take_stats();
            assert_eq!(out1.data, out2.data, "layer {}", cfg.plan.name);
            assert_eq!(stats1.instrs, stats2.instrs, "layer {}", cfg.plan.name);
            assert_eq!(stats1.cycles(), stats2.cycles(), "layer {}", cfg.plan.name);
        }
    }
}

#[test]
fn first_request_stats_match_one_shot_path() {
    // a fresh engine's first request must cost exactly what the one-shot
    // path reports (same buffers, same cold caches, same kernel)
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 1);
    let legacy = run_network(&net.nodes, &inputs[0]);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut engine = EngineMachine::new(&prepared);
    let served = engine.run(&inputs[0]);
    assert_eq!(served.total.instrs, legacy.total.instrs);
    assert_eq!(served.total.cycles(), legacy.total.cycles());
    assert_eq!(served.total.energy_pj, legacy.total.energy_pj);
}

#[test]
fn batcher_closes_on_max_batch() {
    let cfg = BatchConfig { max_batch: 4, max_delay: Duration::from_secs(3600) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let h = dummy_handle("m");
    let mk = |id| Request::infer(id, &h, Tensor::zeros(1, 1, 1), t0);
    assert!(b.push(mk(0)).is_none());
    assert!(b.push(mk(1)).is_none());
    assert!(b.push(mk(2)).is_none());
    let batch = b.push(mk(3)).expect("size trigger closes the batch");
    assert_eq!(batch.requests.len(), 4);
    assert_eq!(batch.target, None);
    let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3]);
    assert!(b.is_empty());
    // with an hour of delay budget the deadline never fires
    assert!(b.poll_deadline(Instant::now()).is_none());
}

#[test]
fn batcher_closes_on_deadline() {
    let cfg = BatchConfig { max_batch: 1000, max_delay: Duration::from_millis(5) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let h = dummy_handle("m");
    let mk = |id| Request::infer(id, &h, Tensor::zeros(1, 1, 1), t0);
    assert!(b.push(mk(0)).is_none());
    assert!(b.push(mk(1)).is_none());
    assert_eq!(b.len(), 2);
    // just before the oldest request's deadline: stays open
    assert!(b.poll_deadline(t0 + Duration::from_millis(4)).is_none());
    // at the deadline: closes with everything pending
    let batch = b.poll_deadline(t0 + Duration::from_millis(5)).expect("deadline trigger");
    assert_eq!(batch.requests.len(), 2);
    assert!(b.next_deadline().is_none());
    // flush drains leftovers on shutdown (and is a no-op when empty)
    assert!(b.flush().is_none());
    assert!(b.push(mk(2)).is_none());
    assert_eq!(b.flush().unwrap().requests.len(), 1);
}

#[test]
fn batcher_groups_by_target_and_closes_fifo() {
    let cfg = BatchConfig { max_batch: 8, max_delay: Duration::from_millis(5) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let h = dummy_handle("m");
    let tok = || Tensor::zeros(1, 1, 1);
    // interleaved arrival: infer, step->w0, infer, step->w1, step->w0
    assert!(b.push(Request::infer(0, &h, tok(), t0)).is_none());
    assert!(b
        .push(Request::step(1, &h, 7, tok(), 0, t0 + Duration::from_micros(1)))
        .is_none());
    assert!(b.push(Request::infer(2, &h, tok(), t0 + Duration::from_micros(2))).is_none());
    assert!(b
        .push(Request::step(3, &h, 8, tok(), 1, t0 + Duration::from_micros(3)))
        .is_none());
    assert!(b
        .push(Request::step(4, &h, 10, tok(), 0, t0 + Duration::from_micros(4)))
        .is_none());
    assert_eq!(b.len(), 5);
    // deadline closes groups FIFO by their oldest request: shared {0,2},
    // then worker-0 {1,4} (same-step sessions batch together), then
    // worker-1 {3} — encode and decode traffic cannot starve each other
    let now = t0 + Duration::from_millis(10);
    let g1 = b.poll_deadline(now).expect("shared group first");
    assert_eq!(g1.target, None);
    assert_eq!(g1.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    let g2 = b.poll_deadline(now).expect("worker-0 group second");
    assert_eq!(g2.target, Some(0));
    assert_eq!(g2.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 4]);
    let g3 = b.poll_deadline(now).expect("worker-1 group last");
    assert_eq!(g3.target, Some(1));
    assert_eq!(g3.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
    assert!(b.poll_deadline(now).is_none());
    assert!(b.is_empty());

    // the size trigger closes only the full group; others keep waiting
    let mut b = DynamicBatcher::new(BatchConfig {
        max_batch: 2,
        max_delay: Duration::from_secs(3600),
    });
    assert!(b.push(Request::infer(0, &h, tok(), t0)).is_none());
    assert!(b.push(Request::step(1, &h, 0, tok(), 1, t0)).is_none());
    let full = b.push(Request::step(2, &h, 1, tok(), 1, t0)).expect("size trigger");
    assert_eq!(full.target, Some(1));
    assert_eq!(full.requests.len(), 2);
    assert_eq!(b.len(), 1);
    assert_eq!(b.flush().unwrap().requests[0].id, 0);
}

#[test]
fn batcher_groups_by_model_and_target() {
    let cfg = BatchConfig { max_batch: 8, max_delay: Duration::from_millis(5) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let tok = || Tensor::zeros(1, 1, 1);
    let ha = dummy_handle("a");
    let hb = dummy_handle("b");
    // same (shared) target, different models: batches never mix, so a
    // worker replays exactly one bind table per batch
    assert!(b.push(Request::infer(0, &ha, tok(), t0)).is_none());
    assert!(b.push(Request::infer(1, &hb, tok(), t0 + Duration::from_micros(1))).is_none());
    assert!(b.push(Request::infer(2, &ha, tok(), t0 + Duration::from_micros(2))).is_none());
    let now = t0 + Duration::from_millis(10);
    let g1 = b.poll_deadline(now).expect("model-a group first (oldest)");
    assert_eq!(g1.model.key.model, "a");
    assert_eq!(g1.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    let g2 = b.poll_deadline(now).expect("model-b group second");
    assert_eq!(g2.model.key.model, "b");
    assert_eq!(g2.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    assert!(b.poll_deadline(now).is_none());

    // same model, different pinned targets still split (decode pinning)
    assert!(b.push(Request::step(3, &ha, 0, tok(), 0, t0)).is_none());
    assert!(b.push(Request::step(4, &ha, 1, tok(), 1, t0)).is_none());
    assert_eq!(b.len(), 2);
    let s1 = b.flush().unwrap();
    let s2 = b.flush().unwrap();
    assert_eq!((s1.target, s2.target), (Some(0), Some(1)));
}

#[test]
fn batcher_edge_cases() {
    let h = dummy_handle("m");
    let mk = |id, t| Request::infer(id, &h, Tensor::zeros(1, 1, 1), t);

    // flush on a never-used empty batcher is a no-op (the dispatcher's
    // shutdown drain loop relies on it)
    let mut b = DynamicBatcher::new(BatchConfig {
        max_batch: 8,
        max_delay: Duration::from_millis(5),
    });
    assert!(b.flush().is_none());
    assert!(b.next_deadline().is_none());

    // the deadline trigger fires at the exact deadline instant (>=, not >)
    let t0 = Instant::now();
    assert!(b.push(mk(0, t0)).is_none());
    let deadline = b.next_deadline().expect("deadline while pending");
    assert_eq!(deadline, t0 + Duration::from_millis(5));
    assert!(b.poll_deadline(deadline - Duration::from_nanos(1)).is_none());
    let batch = b.poll_deadline(deadline).expect("exact-instant close");
    assert_eq!(batch.requests.len(), 1);
    assert!(b.is_empty());

    // max_batch = 0 normalizes to 1: every push closes as its own batch
    let mut b1 = DynamicBatcher::new(BatchConfig {
        max_batch: 0,
        max_delay: Duration::from_secs(3600),
    });
    for id in 0..3u64 {
        let batch = b1.push(mk(id, Instant::now())).expect("size trigger on every push");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.requests[0].id, id);
        assert!(b1.is_empty());
        assert!(b1.next_deadline().is_none());
    }
}

#[test]
fn closed_sessions_free_their_caches_and_restart_empty() {
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    // engine level: end_session drops the KV state, and reusing the id
    // starts from position 0 (bit-identical to the original first step)
    let mut engine = EngineMachine::new(&prepared);
    let tokens = synthetic_step_inputs(&net, 0, 3, 17);
    let first = engine.run_step(5, &tokens[0]);
    engine.run_step(5, &tokens[1]);
    assert_eq!(engine.num_sessions(), 1);
    engine.end_session(5);
    assert_eq!(engine.num_sessions(), 0);
    let restarted = engine.run_step(5, &tokens[0]);
    assert_eq!(first.output.data, restarted.output.data);
    engine.end_session(99); // unknown id: no-op

    // server level: close rides the session FIFO, so all prior steps
    // still complete with their outputs intact
    let cfg = pool_cfg(2, 4);
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let sid = server.open_session();
    for tok in &tokens {
        server.submit_step(sid, tok.clone());
    }
    server.close_session(sid);
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), tokens.len()); // close produces no completion
    assert_eq!(done[0].output.data, first.output.data);
}

#[test]
fn step_after_close_is_rejected_in_caller_not_worker() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = prepare_any(&net);
    let cfg = pool_cfg(2, 4);
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let tokens = synthetic_step_inputs(&net, 0, 3, 11);

    let sid = server.open_session();
    server.submit_step(sid, tokens[0].clone());
    server.close_session(sid);

    // regression: a step after close used to silently re-insert a fresh
    // step guard and ship the step to a worker whose KV caches were
    // already freed — restarting the session (or panicking the worker
    // and every co-located session with it). It must fail here instead.
    let stale = catch_unwind(AssertUnwindSafe(|| {
        server.submit_step(sid, tokens[1].clone());
    }));
    assert!(stale.is_err(), "step on a closed session must fail in the caller's thread");

    // double close and never-opened sessions are caller errors too
    let closed_twice = catch_unwind(AssertUnwindSafe(|| server.close_session(sid)));
    assert!(closed_twice.is_err());
    let never_opened = catch_unwind(AssertUnwindSafe(|| {
        server.submit_step(SessionId(999), tokens[0].clone());
    }));
    assert!(never_opened.is_err());

    // the pool is unharmed: a new session still serves steps, and
    // shutdown joins every worker cleanly (a dead thread would be
    // surfaced through `faults()`)
    let sid2 = server.open_session();
    server.submit_step(sid2, tokens[0].clone());
    server.submit_step(sid2, tokens[1].clone());
    server.close_session(sid2);
    let done = server.shutdown();
    assert!(server.faults().is_none(), "caller-side panics must not kill serving threads");
    assert_eq!(done.len(), 3, "1 step before close + 2 steps on the new session");
    assert!(done.iter().all(|c| c.output.data.iter().all(|v| v.is_finite())));
}

#[test]
fn concurrent_workers_are_deterministic_and_bit_exact() {
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 24);
    let legacy: Vec<Vec<f32>> =
        inputs.iter().map(|x| run_network(&net.nodes, x).output.data.clone()).collect();
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let cfg = pool_cfg(3, 4);
    let run1 = serve_all(&prepared, &cfg, inputs.clone());
    assert_eq!(run1.len(), inputs.len());
    for c in &run1 {
        assert_eq!(c.output.data, legacy[c.id as usize], "request {}", c.id);
        assert!(c.batch_size >= 1 && c.batch_size <= 4);
        assert!(c.worker < 3);
        assert_eq!(c.session, None);
    }
    // a second serving run over the same prepared model reproduces every
    // output exactly, regardless of worker/batch scheduling
    let run2 = serve_all(&prepared, &cfg, inputs.clone());
    assert_eq!(run1.len(), run2.len());
    for (a, b) in run1.iter().zip(&run2) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output.data, b.output.data, "request {}", a.id);
    }
}

#[test]
fn tinyattn_prepared_matches_one_shot_under_4_workers() {
    let (net, inputs) = net_and_inputs("tinyattn", DesignPoint::Patterns(4), 16);
    let legacy: Vec<Vec<f32>> =
        inputs.iter().map(|x| run_network(&net.nodes, x).output.data.clone()).collect();
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    // 2 blocks x (wq, wk, wv, qk, av, wo, ff1, ff2) prepared kernels
    assert_eq!(prepared.num_layers(), 16);
    for max_batch in [1usize, 4] {
        let cfg = pool_cfg(4, max_batch);
        let done = serve_all(&prepared, &cfg, inputs.clone());
        assert_eq!(done.len(), inputs.len());
        for c in &done {
            assert_eq!(
                c.output.data,
                legacy[c.id as usize],
                "request {} (max_batch {max_batch})",
                c.id
            );
            assert!(c.output.data.iter().all(|v| v.is_finite()));
            assert_eq!(c.per_layer.len(), 16);
        }
    }
}

#[test]
fn tinyattn_dynamic_operands_deterministic_across_placement() {
    // QK^T / A·V pack their "weight" operand per request into per-worker
    // scratch — the same request must produce bit-identical results no
    // matter which worker or batch slot it lands in, and no matter how
    // warm the worker's machine already is.
    let (net, inputs) = net_and_inputs("tinyattn", DesignPoint::Patterns(8), 1);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut engine = EngineMachine::new(&prepared);
    let reference = engine.run(&inputs[0]);
    let again = engine.run(&inputs[0]); // warm machine, same request
    assert_eq!(reference.output.data, again.output.data);
    assert_eq!(reference.total.instrs, again.total.instrs);

    let cfg = pool_cfg(4, 3);
    let copies = vec![inputs[0].clone(); 12];
    let done = serve_all(&prepared, &cfg, copies);
    assert_eq!(done.len(), 12);
    for c in &done {
        assert_eq!(
            c.output.data, reference.output.data,
            "request {} on worker {} batch {}",
            c.id, c.worker, c.batch_id
        );
    }
}

#[test]
fn one_pool_serves_three_models_bit_identical_to_dedicated_servers() {
    // the tentpole contract: tinynet + tinyattn + tinydec interleaved
    // through ONE worker pool, outputs bit-identical to what each model
    // gets from a pool of its own
    let dp = DesignPoint::Patterns(4);
    let n = 6usize;
    let mut fleet = Vec::new(); // (key, prepared, inputs)
    for name in ["tinynet", "tinyattn", "tinydec"] {
        let net = synthetic_network(name, dp, 3).unwrap();
        let inputs = synthetic_inputs(&net, n, 5);
        fleet.push((ModelKey::new(name, dp.label()), prepare_any(&net), inputs));
    }

    // dedicated single-model pools: the parity oracle
    let dedicated: Vec<Vec<Vec<f32>>> = fleet
        .iter()
        .map(|(key, prepared, inputs)| {
            let mut server =
                Server::start_named(key.clone(), Arc::clone(prepared), &pool_cfg(2, 4));
            for x in inputs {
                server.submit(x.clone());
            }
            let mut done = server.shutdown();
            done.sort_by_key(|c| c.id);
            done.into_iter().map(|c| c.output.data).collect()
        })
        .collect();

    // one shared pool, round-robin interleaved traffic
    let mut server = Server::start_pool(&pool_cfg(3, 4));
    for (key, prepared, _) in &fleet {
        server.register(key.clone(), Arc::clone(prepared));
    }
    assert_eq!(server.model_keys().len(), 3);
    for i in 0..n {
        for (key, _, inputs) in &fleet {
            server.submit_model(key, inputs[i].clone());
        }
    }
    let mut done = server.shutdown();
    let wall = Duration::from_millis(50);
    assert_eq!(done.len(), 3 * n);
    done.sort_by_key(|c| c.id);
    let mut seen_models: HashSet<ModelKey> = HashSet::new();
    for c in &done {
        // ids were assigned round-robin: id = i * n_models + mi
        let mi = (c.id as usize) % fleet.len();
        let ri = (c.id as usize) / fleet.len();
        assert_eq!(*c.model, fleet[mi].0, "completion {} carries its model", c.id);
        assert_eq!(c.output.data, dedicated[mi][ri], "model {} request {ri}", fleet[mi].0);
        seen_models.insert((*c.model).clone());
    }
    assert_eq!(seen_models.len(), 3, "all three models served concurrently");

    // and the report aggregates per model and per (model, layer)
    let report = summarize(&done, wall, SetupTiming::default());
    assert_eq!(report.per_model.len(), 3);
    assert!(report.per_model.iter().all(|m| m.requests == n));
    for m in &report.per_model {
        assert!(m.cycles > 0);
        assert!(report.per_layer.iter().any(|l| l.model == m.model));
    }
}

#[test]
fn lru_eviction_rebinds_models_correctly() {
    let dp = DesignPoint::Patterns(4);
    let (net_a, in_a) = net_and_inputs("tinynet", dp, 1);
    let (net_b, in_b) = net_and_inputs("tinydw", dp, 1);
    let pa = Arc::new(PreparedModel::prepare(&net_a.nodes));
    let pb = Arc::new(PreparedModel::prepare(&net_b.nodes));
    let ka = ModelKey::new("tinynet", "P4");
    let kb = ModelKey::new("tinydw", "P4");
    let ha = ModelHandle::new(ka.clone(), Arc::clone(&pa));
    let hb = ModelHandle::new(kb.clone(), Arc::clone(&pb));
    let want_a = {
        let mut e = EngineMachine::new(&pa);
        e.run(&in_a[0]).output.data
    };
    let want_b = {
        let mut e = EngineMachine::new(&pb);
        e.run(&in_b[0]).output.data
    };

    // budget 1: every alternation evicts the other model's bind table
    // and rebinds from the handle — outputs must never drift
    let mut engine = EngineMachine::with_budget(1);
    for round in 0..3 {
        let got_a = engine.run_model(&ha, &in_a[0]);
        assert_eq!(engine.num_resident(), 1);
        let got_b = engine.run_model(&hb, &in_b[0]);
        assert_eq!(engine.num_resident(), 1);
        assert_eq!(got_a.output.data, want_a, "round {round}");
        assert_eq!(got_b.output.data, want_b, "round {round}");
    }

    // budget 2: both stay resident, no churn
    let mut engine = EngineMachine::with_budget(2);
    engine.run_model(&ha, &in_a[0]);
    engine.run_model(&hb, &in_b[0]);
    assert_eq!(engine.num_resident(), 2);

    // pool level: a 1-model budget under interleaved two-model traffic
    let cfg = ServeConfig {
        workers: 1,
        batch: BatchConfig { max_batch: 2, max_delay: Duration::from_millis(1) },
        resident_models: 1,
        worker_budget: None,
        trace: false,
        queue_depth: None,
        kv: None,
    };
    let mut server = Server::start_pool(&cfg);
    server.register(ka.clone(), Arc::clone(&pa));
    server.register(kb.clone(), Arc::clone(&pb));
    for _ in 0..3 {
        server.submit_model(&ka, in_a[0].clone());
        server.submit_model(&kb, in_b[0].clone());
    }
    let done = server.shutdown();
    assert_eq!(done.len(), 6);
    for c in &done {
        let want = if c.model.model == "tinynet" { &want_a } else { &want_b };
        assert_eq!(&c.output.data, want, "request {}", c.id);
    }
}

#[test]
fn machine_recycles_freed_buffer_slots() {
    // sustained bind/evict churn must be bounded by peak live buffers,
    // not total ever allocated (the id space is u16)
    use soniq::sim::machine::Machine;
    let mut m = Machine::new();
    let a = m.alloc(64);
    let live = m.resident_bytes();
    m.free(a);
    assert!(m.resident_bytes() < live, "free must release backing bytes");
    let b = m.alloc(128);
    assert_eq!(a, b, "freed id slot must be recycled");
    // far more alloc/free cycles than the id space holds
    for _ in 0..100_000 {
        let x = m.alloc(4096);
        m.free(x);
    }
}

#[test]
fn register_rejects_conflicting_reprepare_under_same_key() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let (net, _) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 1);
    let pa = Arc::new(PreparedModel::prepare(&net.nodes));
    let pa2 = Arc::new(PreparedModel::prepare(&net.nodes)); // distinct instance
    let key = ModelKey::new("tinynet", "P4");
    let mut server = Server::start_pool(&pool_cfg(1, 2));
    server.register(key.clone(), Arc::clone(&pa));
    // same instance again: a no-op
    server.register(key.clone(), Arc::clone(&pa));
    assert_eq!(server.model_keys().len(), 1);
    // a different instance under a taken key would make workers replay
    // the old bind table for the new model's requests — refused
    let clash =
        catch_unwind(AssertUnwindSafe(|| server.register(key.clone(), Arc::clone(&pa2))));
    assert!(clash.is_err(), "conflicting re-registration must be rejected");
    server.shutdown();
}

#[test]
fn engine_rejects_session_id_reuse_across_models() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = prepare_any(&net);
    let h1 = ModelHandle::new(ModelKey::new("dec", "A"), Arc::clone(&prepared));
    let h2 = ModelHandle::new(ModelKey::new("dec", "B"), Arc::clone(&prepared));
    let tokens = synthetic_step_inputs(&net, 0, 2, 21);
    let mut engine = EngineMachine::with_budget(4);
    engine.run_step_model(&h1, 7, &tokens[0]);
    // a session id is meaningful only within its model: stepping it
    // through another model's handle would corrupt the KV slot layout
    let clash = catch_unwind(AssertUnwindSafe(|| {
        engine.run_step_model(&h2, 7, &tokens[1]);
    }));
    assert!(clash.is_err(), "cross-model session id reuse must be rejected");
    // ending the session releases the id for any model
    engine.end_session(7);
    engine.run_step_model(&h2, 7, &tokens[0]);
}

#[test]
fn evicted_decoder_rebinds_with_sessions_intact() {
    // KV caches are host-side session state, not machine buffers:
    // evicting a decoder between steps must not lose the session
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinydec", dp, 3).unwrap();
    let prepared = prepare_any(&net);
    let hd = ModelHandle::new(ModelKey::new("tinydec", "P4"), Arc::clone(&prepared));
    let (net_b, in_b) = net_and_inputs("tinynet", dp, 1);
    let pb = Arc::new(PreparedModel::prepare(&net_b.nodes));
    let hb = ModelHandle::new(ModelKey::new("tinynet", "P4"), Arc::clone(&pb));
    let tokens = synthetic_step_inputs(&net, 0, 4, 17);

    // oracle: the same session stepped on a dedicated engine
    let mut oracle = EngineMachine::new(&prepared);
    let want: Vec<Vec<f32>> =
        tokens.iter().map(|t| oracle.run_step(7, t).output.data.clone()).collect();

    let mut engine = EngineMachine::with_budget(1);
    for (t, tok) in tokens.iter().enumerate() {
        let got = engine.run_step_model(&hd, 7, tok);
        assert_eq!(got.output.data, want[t], "step {t} after eviction/rebind");
        engine.run_model(&hb, &in_b[0]); // evicts the decoder
        assert_eq!(engine.num_resident(), 1);
    }
    assert!(engine.session_kv_bytes() > 0);
    engine.end_session(7);
    assert_eq!(engine.session_kv_bytes(), 0);
}

#[test]
fn cached_decode_matches_prefix_rerun_and_costs_fewer_cycles() {
    // the decode contract: every cached decode step is bit-identical
    // to re-running its full prefix through the one-shot causal graph,
    // at a fraction of the simulated cycles
    let dp = DesignPoint::Patterns(8);
    let net = synthetic_network("tinydec", dp, 5).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    let mut engine = EngineMachine::new(&prepared);
    let steps = 6usize;
    let tokens = synthetic_step_inputs(&net, 0, steps, 13);
    let mut cached_cycles = 0u64;
    let mut baseline_cycles = 0u64;
    for t in 0..steps {
        let step_res = engine.run_step(42, &tokens[t]);
        cached_cycles += step_res.total.cycles();
        let net_t = synthetic_network_seq("tinydec", dp, 5, Some(t + 1)).unwrap();
        let (h, w, c) = net_t.input_shape;
        let mut data = Vec::new();
        for tok in tokens.iter().take(t + 1) {
            data.extend_from_slice(&tok.data);
        }
        let full = run_network(&net_t.nodes, &Tensor { h, w, c, data });
        baseline_cycles += full.total.cycles();
        assert_eq!(
            step_res.output.data[..],
            full.output.data[t * c..(t + 1) * c],
            "decode step {t} != one-shot prefix row"
        );
        assert!(step_res.output.data.iter().all(|v| v.is_finite()));
    }
    assert_eq!(engine.num_sessions(), 1);
    assert!(
        cached_cycles < baseline_cycles,
        "cached decode ({cached_cycles} cycles) must beat prefix repack ({baseline_cycles})"
    );
}

#[test]
fn footprint_placement_spreads_sessions_and_never_splits() {
    // session placement follows the KV-byte footprint: a worker loaded
    // with a long-prefix session stops receiving new sessions, and no
    // session's steps ever land on two workers
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinydec", dp, 3).unwrap();
    let prepared = prepare_any(&net);
    let key = ModelKey::new("tinydec", dp.label());
    let mut server = Server::start_pool(&pool_cfg(3, 4));
    server.register(key.clone(), Arc::clone(&prepared));

    let tokens: Vec<Vec<Tensor>> =
        (0..4).map(|k| synthetic_step_inputs(&net, k, 6, 9)).collect();
    // s0 gets a heavy prefix before anyone else opens
    let s0 = server.open_session_on(&key);
    for t in 0..6 {
        server.submit_step(s0, tokens[0][t].clone());
    }
    let s1 = server.open_session_on(&key);
    for t in 0..2 {
        server.submit_step(s1, tokens[1][t].clone());
    }
    let s2 = server.open_session_on(&key);
    server.submit_step(s2, tokens[2][0].clone());
    let s3 = server.open_session_on(&key);
    server.submit_step(s3, tokens[3][0].clone());
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 6 + 2 + 1 + 1);

    let mut worker_of: HashMap<u64, usize> = HashMap::new();
    for c in &done {
        let sid = c.session.expect("decode completion carries its session");
        match worker_of.get(&sid) {
            Some(&w) => assert_eq!(w, c.worker, "session {sid} split across workers"),
            None => {
                worker_of.insert(sid, c.worker);
            }
        }
    }
    assert_eq!(worker_of.len(), 4);
    // every later session avoided s0's loaded worker, and s2 avoided
    // s1's bytes too — footprint, not round-robin
    let w0 = worker_of[&s0.0];
    assert_ne!(worker_of[&s1.0], w0, "heaviest worker must not get the next session");
    assert_ne!(worker_of[&s2.0], w0);
    assert_ne!(worker_of[&s3.0], w0);
    assert_ne!(worker_of[&s2.0], worker_of[&s1.0]);
    let used: HashSet<usize> = worker_of.values().copied().collect();
    assert_eq!(used.len(), 3, "sessions spread across the whole pool");
}

#[test]
fn decode_sessions_stay_on_one_worker_each() {
    // every step of a session lands on the worker that owns its KV
    // cache, across many interleaved sessions; with all sessions opened
    // up front (equal footprints) placement spreads them evenly
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    let cfg = pool_cfg(3, 4);
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let sids: Vec<SessionId> = (0..6).map(|_| server.open_session()).collect();
    let steps = 5usize;
    let tokens: Vec<Vec<Tensor>> =
        (0..6).map(|k| synthetic_step_inputs(&net, k, steps, 9)).collect();
    for t in 0..steps {
        for (si, sid) in sids.iter().enumerate() {
            server.submit_step(*sid, tokens[si][t].clone());
        }
    }
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 6 * steps);
    let mut worker_of: HashMap<u64, usize> = HashMap::new();
    let mut steps_of: HashMap<u64, usize> = HashMap::new();
    for c in &done {
        let sid = c.session.expect("decode completion carries its session");
        *steps_of.entry(sid).or_insert(0) += 1;
        match worker_of.get(&sid) {
            Some(&w) => assert_eq!(w, c.worker, "session {sid} split across workers"),
            None => {
                worker_of.insert(sid, c.worker);
            }
        }
    }
    assert_eq!(worker_of.len(), 6);
    let mut sessions_per_worker = [0usize; 3];
    for w in worker_of.values() {
        sessions_per_worker[*w] += 1;
    }
    assert_eq!(sessions_per_worker, [2, 2, 2], "equal-footprint sessions spread evenly");
    assert!(steps_of.values().all(|&n| n == steps));

    // deterministic: the served outputs match a single-engine replay
    let mut engine = EngineMachine::new(&prepared);
    for c in &done {
        if c.session == Some(sids[0].0) {
            let t = (c.id as usize) / sids.len(); // step-major submission
            let want = engine.run_step(999, &tokens[0][t]);
            assert_eq!(c.output.data, want.output.data, "session 0 step {t}");
        }
    }
}

#[test]
fn transpose_hw_swaps_axes_and_roundtrips() {
    use soniq::sim::network::{Node, INPUT};
    let t = Tensor { h: 3, w: 5, c: 2, data: (0..30).map(|i| i as f32).collect() };
    let once = run_network(&[Node::TransposeHW { x: INPUT }], &t);
    assert_eq!((once.output.h, once.output.w, once.output.c), (5, 3, 2));
    for h in 0..3 {
        for w in 0..5 {
            for c in 0..2 {
                assert_eq!(once.output.at(w, h, c), t.at(h, w, c), "h{h} w{w} c{c}");
            }
        }
    }
    // transposing twice is the identity
    let twice = run_network(&[Node::TransposeHW { x: INPUT }, Node::TransposeHW { x: 0 }], &t);
    assert_eq!(twice.output.data, t.data);
}

#[test]
fn registry_prepares_once_per_key() {
    let (net, _) = net_and_inputs("tinynet", DesignPoint::Uniform(4), 1);
    let reg = ModelRegistry::new();
    let key = ModelKey::new("tinynet", "U4");
    assert_eq!(key.to_string(), "tinynet/U4");
    assert!(!reg.contains(&key));
    let mut builds = 0u32;
    let a = reg.get_or_prepare(&key, || {
        builds += 1;
        PreparedModel::prepare(&net.nodes)
    });
    let b = reg.get_or_prepare(&key, || {
        builds += 1;
        PreparedModel::prepare(&net.nodes)
    });
    assert!(Arc::ptr_eq(&a, &b));
    assert_eq!(builds, 1, "model must be prepared exactly once per key");
    assert!(reg.contains(&key));
    assert_eq!(reg.len(), 1);
    assert_eq!(a.num_layers(), 4);
}

/// A synthetic completion for metrics-only tests (never executed).
fn fake_completion(id: u64, key: &ModelKey, layer: &str, cycles: u64) -> Completion {
    let stats = RunStats { alu_cycles: cycles, ..RunStats::default() };
    Completion {
        id,
        model: Arc::new(key.clone()),
        worker: 0,
        batch_id: id,
        batch_size: 1,
        latency: Duration::from_millis(1 + id),
        session: None,
        shard: None,
        output: Tensor::zeros(1, 1, 1),
        total: stats.clone(),
        per_layer: vec![LayerStat { name: layer.to_string(), shard: None, stats }],
        spans: soniq::serve::SpanTrack::new(Instant::now()),
    }
}

#[test]
fn metrics_never_merge_layers_across_models() {
    // regression: per-layer aggregation used to key by bare layer name,
    // silently merging two models' cycles/energy whenever their layer
    // names collided (which synthetic twins always do)
    let ka = ModelKey::new("alpha", "P4");
    let kb = ModelKey::new("beta", "P4");
    let done = vec![
        fake_completion(0, &ka, "c1", 100),
        fake_completion(1, &kb, "c1", 40),
        fake_completion(2, &ka, "c1", 100),
    ];
    let report = summarize(&done, Duration::from_millis(10), SetupTiming::default());
    assert_eq!(report.per_model.len(), 2);
    assert_eq!(report.per_layer.len(), 2, "shared layer name must not merge across models");
    let a = report.per_layer.iter().find(|l| l.model == "alpha/P4").unwrap();
    let b = report.per_layer.iter().find(|l| l.model == "beta/P4").unwrap();
    assert_eq!((a.name.as_str(), a.cycles), ("c1", 200));
    assert_eq!((b.name.as_str(), b.cycles), ("c1", 40));
    let alpha = report.per_model.iter().find(|m| m.model == "alpha/P4").unwrap();
    assert_eq!((alpha.requests, alpha.cycles), (2, 200));

    // JSON rows carry the model dimension
    let text = report.to_json().to_string();
    let parsed = soniq::util::json::parse(&text).unwrap();
    let layers = parsed.get("per_layer").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), 2);
    assert!(layers.iter().all(|l| l.get("model").is_ok()));
    assert_eq!(parsed.get("per_model").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn steady_rps_is_null_when_bind_swallows_the_window() {
    // regression: `bind >= wall` used to divide by the 1e-9 clamp and
    // report absurd throughput for tiny runs; an empty steady window
    // has no steady state to report
    let key = ModelKey::new("m", "P4");
    let done = vec![fake_completion(0, &key, "l", 1)];
    let setup = SetupTiming { prepare: Duration::ZERO, bind: Duration::from_millis(5) };
    let report = summarize(&done, Duration::from_millis(5), setup);
    assert!(report.steady_rps.is_nan(), "empty steady window must not fake throughput");
    assert!(report.throughput_rps > 0.0);
    let parsed = soniq::util::json::parse(&report.to_json().to_string()).unwrap();
    assert!(
        matches!(parsed.get("steady_throughput_rps"), Ok(soniq::util::json::Json::Null)),
        "NaN steady_rps must serialize as JSON null"
    );
    // bind > wall (clocks measured on different threads) is the same
    let report = summarize(&done, Duration::from_millis(3), setup);
    assert!(report.steady_rps.is_nan());
    // a residual window inside cross-thread measurement jitter (here
    // 100 ns of a 5 ms run) must not become a fantasy denominator
    let jitter = SetupTiming {
        prepare: Duration::ZERO,
        bind: Duration::from_millis(5) - Duration::from_nanos(100),
    };
    let report = summarize(&done, Duration::from_millis(5), jitter);
    assert!(report.steady_rps.is_nan());
    // a real window still reports a number
    let report = summarize(&done, Duration::from_millis(6), setup);
    assert!(report.steady_rps.is_finite());
}

#[test]
fn serve_report_aggregates_and_serializes() {
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Uniform(4), 12);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let cfg = pool_cfg(2, 4);
    let t0 = Instant::now();
    let done = serve_all(&prepared, &cfg, inputs);
    let setup = SetupTiming {
        prepare: Duration::from_millis(3),
        bind: Duration::from_micros(500),
    };
    let report = summarize(&done, t0.elapsed(), setup);
    assert_eq!(report.requests, 12);
    assert!(report.batches >= 3 && report.batches <= 12, "batches {}", report.batches);
    assert!(report.mean_batch_size >= 1.0 && report.mean_batch_size <= 4.0);
    assert!(report.throughput_rps > 0.0);
    // steady-state excludes bind time, so when a window exists it can
    // only be faster (NaN = the whole wall was bind, possible only on
    // a degenerate-fast run)
    assert!(report.steady_rps.is_nan() || report.steady_rps >= report.throughput_rps);
    assert_eq!(report.setup.prepare, Duration::from_millis(3));
    assert!(report.p50_ms <= report.p99_ms);
    assert!(report.sim.cycles() > 0 && report.sim.energy_pj > 0.0);
    // a single-model run has one model aggregate carrying every request
    assert_eq!(report.per_model.len(), 1);
    assert_eq!(report.per_model[0].requests, 12);
    // one aggregate per conv/FC layer: c1, c2, c3, fc
    assert_eq!(report.per_layer.len(), 4);
    assert!(report.per_layer.iter().all(|l| l.cycles > 0));
    // JSON round-trips through the offline parser
    let text = report.to_json().to_string();
    let parsed = soniq::util::json::parse(&text).unwrap();
    assert_eq!(parsed.get("requests").unwrap().as_usize().unwrap(), 12);
    assert_eq!(parsed.get("per_layer").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(parsed.get("per_model").unwrap().as_arr().unwrap().len(), 1);
    assert!(parsed.get("prepare_ms").is_ok());
    assert!(parsed.get("bind_ms").is_ok());
    assert!(parsed.get("steady_throughput_rps").is_ok());
    // schema versioning: bench tooling detects the per-shard layer keys
    // from this field instead of guessing from row shapes
    assert_eq!(parsed.get("schema").unwrap().as_usize().unwrap(), SERVE_REPORT_SCHEMA as usize);
}

// ---------------------------------------------------------------------
// shard-aware deployment: scatter/gather serving
// ---------------------------------------------------------------------

#[test]
fn sharded_tinywide_is_bit_identical_to_unsharded() {
    // the tentpole contract: tinywide's wide layer split across >= 2
    // workers, scatter/gathered outputs bit-identical to the whole
    // model on one unbudgeted machine
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinywide", dp, 3).unwrap();
    let inputs = synthetic_inputs(&net, 8, 5);
    let key = ModelKey::new("tinywide", dp.label());
    let whole = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut oracle = EngineMachine::new(&whole);
    let want: Vec<Vec<f32>> = inputs.iter().map(|x| oracle.run(x).output.data.clone()).collect();

    // 3 shards on 2 workers also exercises two shards co-resident on
    // one machine (their shard-tagged keys keep bind tables distinct)
    for shards in [2usize, 3] {
        let dcfg = DeployConfig { worker_budget: None, shards: Some(shards) };
        let dep = Arc::new(Deployment::build(key.clone(), &net.nodes, None, &dcfg).unwrap());
        assert_eq!(dep.num_shards(), shards);
        assert!(dep.is_sharded());
        let mut server = Server::start_deployment(Arc::clone(&dep), &pool_cfg(2, 4));
        for x in &inputs {
            server.submit(x.clone());
        }
        let mut done = server.shutdown();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), inputs.len(), "one gathered completion per request");
        for c in &done {
            assert_eq!(c.output.data, want[c.id as usize], "{shards} shards, request {}", c.id);
            assert_eq!(c.shard, None, "callers see gathered completions only");
            assert_eq!(*c.model, key, "gathered completions carry the base key");
            // per-shard attribution: every shard contributed layer stats
            let tags: HashSet<Option<usize>> = c.per_layer.iter().map(|l| l.shard).collect();
            assert_eq!(tags.len(), shards, "request {}", c.id);
            assert!((0..shards).all(|i| tags.contains(&Some(i))));
        }
    }
}

#[test]
fn over_budget_model_serves_only_via_sharding() {
    // acceptance: a model whose widest layer exceeds one machine's
    // buffer budget cannot bind whole, and serves bit-exactly sharded
    use soniq::serve::engine::conv_bind_bytes;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinywide", dp, 3).unwrap();
    let inputs = synthetic_inputs(&net, 4, 9);
    let key = ModelKey::new("tinywide", dp.label());
    let whole = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut oracle = EngineMachine::new(&whole);
    let want: Vec<Vec<f32>> = inputs.iter().map(|x| oracle.run(x).output.data.clone()).collect();

    // budget = exactly the wide layer's own bind footprint: the whole
    // model (wide + stem + fc) can never fit one machine
    let Node::Conv { cfg: wide_cfg, .. } = &net.nodes[1] else {
        panic!("tinywide node 1 is the wide conv");
    };
    let budget = conv_bind_bytes(&wide_cfg.plan);
    let whole_handle = ModelHandle::new(key.clone(), Arc::clone(&whole));
    let blocked = catch_unwind(AssertUnwindSafe(|| {
        let mut engine = EngineMachine::with_limits(usize::MAX, Some(budget));
        engine.bind_model(&whole_handle);
    }));
    assert!(blocked.is_err(), "whole-model bind must exceed the {budget} B budget");

    // the budget-derived deployment shards automatically and serves
    // through budgeted workers
    let dcfg = DeployConfig { worker_budget: Some(budget), shards: None };
    let dep = Arc::new(Deployment::build(key.clone(), &net.nodes, None, &dcfg).unwrap());
    assert!(dep.is_sharded(), "plan: {}", dep.describe());
    let cfg = ServeConfig { worker_budget: Some(budget), ..pool_cfg(2, 4) };
    let mut server = Server::start_deployment(Arc::clone(&dep), &cfg);
    for x in &inputs {
        server.submit(x.clone());
    }
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), inputs.len());
    for c in &done {
        assert_eq!(c.output.data, want[c.id as usize], "request {}", c.id);
    }
}

#[test]
fn sharded_report_keys_layers_by_model_layer_shard() {
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinywide", dp, 3).unwrap();
    let inputs = synthetic_inputs(&net, 4, 7);
    let key = ModelKey::new("tinywide", dp.label());
    let dcfg = DeployConfig { worker_budget: None, shards: Some(2) };
    let dep = Arc::new(Deployment::build(key.clone(), &net.nodes, None, &dcfg).unwrap());

    // deploy into a pool (the registered-model form of the sharded path)
    let mut server = Server::start_pool(&pool_cfg(2, 4));
    server.deploy(Arc::clone(&dep));
    assert!(server.deployment(&key).is_some_and(|d| d.is_sharded()));
    for x in &inputs {
        server.submit_model(&key, x.clone());
    }
    let done = server.shutdown();
    assert_eq!(done.len(), inputs.len());

    let report = summarize(&done, Duration::from_millis(10), SetupTiming::default());
    assert_eq!(report.per_model.len(), 1, "shards aggregate under the base model");
    assert_eq!(report.per_model[0].requests, inputs.len());
    // wide runs sliced on both shards: one LayerAgg per (layer, shard)
    let wide: Vec<_> = report.per_layer.iter().filter(|l| l.name == "wide").collect();
    assert_eq!(wide.len(), 2, "one aggregate per shard of the wide layer");
    assert!(wide.iter().all(|l| l.shard.is_some() && l.cycles > 0));

    let parsed = soniq::util::json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("schema").unwrap().as_usize().unwrap(), SERVE_REPORT_SCHEMA as usize);
    let layers = parsed.get("per_layer").unwrap().as_arr().unwrap();
    assert!(layers.iter().all(|l| l.get("shard").is_ok()), "layer rows carry shard");
}

#[test]
fn tinyattn_deploys_whole_and_refuses_forced_sharding() {
    // sharded-vs-whole on tinyattn: under any realistic budget the plan
    // degenerates to Whole (the PR-4 path), bit-identical end to end;
    // forcing a split is refused because its wide GEMM feeds mid-graph
    // consumers (residual adds), where a gather would be required
    // mid-request — refusing beats serving wrong numbers
    let dp = DesignPoint::Patterns(4);
    let (net, inputs) = net_and_inputs("tinyattn", dp, 6);
    let key = ModelKey::new("tinyattn", dp.label());
    let dcfg = DeployConfig { worker_budget: Some(1 << 26), shards: None };
    let dep = Arc::new(Deployment::build(key.clone(), &net.nodes, None, &dcfg).unwrap());
    assert!(!dep.is_sharded());
    assert!(matches!(dep.plan(), ShardPlan::Whole));

    let legacy: Vec<Vec<f32>> =
        inputs.iter().map(|x| run_network(&net.nodes, x).output.data.clone()).collect();
    let mut server = Server::start_deployment(Arc::clone(&dep), &pool_cfg(2, 4));
    for x in &inputs {
        server.submit(x.clone());
    }
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), inputs.len());
    for c in &done {
        assert_eq!(c.output.data, legacy[c.id as usize], "request {}", c.id);
        assert!(c.per_layer.iter().all(|l| l.shard.is_none()));
    }

    let force = DeployConfig { worker_budget: None, shards: Some(2) };
    let forced = Deployment::build(key, &net.nodes, None, &force);
    assert!(forced.is_err(), "tinyattn's split axis feeds mid-graph consumers");
}

#[test]
fn concat_gather_via_engines_matches_whole() {
    // a graph whose wide layer IS the output: gather = channel concat.
    // Shards run on plain engines here — Deployment::gather_outputs is
    // the same assembly the server's gather buffer uses.
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinywide", dp, 3).unwrap();
    let head = &net.nodes[..2]; // c1 + wide: the wide tensor is the output
    let key = ModelKey::new("tinywide-head", dp.label());
    let dcfg = DeployConfig { worker_budget: None, shards: Some(2) };
    let dep = Deployment::build(key, head, None, &dcfg).unwrap();
    assert!(matches!(
        dep.plan(),
        ShardPlan::Sharded { gather: GatherMode::Concat, consumer_node: None, .. }
    ));
    let x = synthetic_inputs(&net, 1, 5).remove(0);
    let whole = run_network(head, &x);
    let parts: Vec<Tensor> = dep
        .handles()
        .iter()
        .map(|h| EngineMachine::new(&h.prepared).run(&x).output)
        .collect();
    let refs: Vec<&Tensor> = parts.iter().collect();
    assert_eq!(dep.gather_outputs(&refs).data, whole.output.data);
}

#[test]
fn sharded_decoders_are_refused() {
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let key = ModelKey::new("tinydec", "P4");
    let step = net.step_nodes.as_deref();
    let force = DeployConfig { worker_budget: None, shards: Some(2) };
    let forced = Deployment::build(key.clone(), &net.nodes, step, &force);
    assert!(forced.is_err(), "KV sessions pin whole models");
    // without a forced split, decoders deploy whole and keep serving
    let dep = Deployment::build(key, &net.nodes, step, &DeployConfig::default()).unwrap();
    assert!(!dep.is_sharded());
    assert!(dep.handles()[0].prepared.step.is_some(), "decoder form preserved");
}

#[test]
fn capacity_eviction_swaps_models_instead_of_panicking() {
    // two models that each fit a budgeted machine alone but not
    // together: bind_model evicts the LRU one to make byte room (the
    // multi-deployment analogue of the resident-count LRU), so budgeted
    // pools serving several models churn instead of panicking a worker
    let dp = DesignPoint::Patterns(4);
    let (net_a, in_a) = net_and_inputs("tinynet", dp, 1);
    let (net_b, in_b) = net_and_inputs("tinydw", dp, 1);
    let pa = Arc::new(PreparedModel::prepare(&net_a.nodes));
    let pb = Arc::new(PreparedModel::prepare(&net_b.nodes));
    let budget = pa.bind_bytes().max(pb.bind_bytes()) + 1024;
    assert!(pa.bind_bytes() + pb.bind_bytes() > budget, "budget must not fit both");
    let ha = ModelHandle::new(ModelKey::new("a", "P4"), Arc::clone(&pa));
    let hb = ModelHandle::new(ModelKey::new("b", "P4"), Arc::clone(&pb));
    let want_a = EngineMachine::new(&pa).run(&in_a[0]).output.data;
    let want_b = EngineMachine::new(&pb).run(&in_b[0]).output.data;

    let mut engine = EngineMachine::with_limits(usize::MAX, Some(budget));
    for round in 0..2 {
        assert_eq!(engine.run_model(&ha, &in_a[0]).output.data, want_a, "round {round}");
        assert_eq!(engine.run_model(&hb, &in_b[0]).output.data, want_b, "round {round}");
        assert_eq!(engine.num_resident(), 1, "byte budget keeps one model resident");
    }
}

#[test]
fn budgeted_pools_refuse_more_shards_than_workers() {
    // a shard plan sizes every shard for a machine of its own; wrapping
    // two shards onto one *budgeted* worker could exceed its buffer
    // budget mid-serve, so placement refuses it up front (unbudgeted
    // pools still allow co-residency — covered by the 3-shards-on-2-
    // workers case above)
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinywide", dp, 3).unwrap();
    let key = ModelKey::new("tinywide", dp.label());
    let dcfg = DeployConfig { worker_budget: None, shards: Some(3) };
    let dep = Arc::new(Deployment::build(key.clone(), &net.nodes, None, &dcfg).unwrap());
    let cfg = ServeConfig { worker_budget: Some(1 << 20), ..pool_cfg(2, 4) };
    let mut server = Server::start_pool(&cfg);
    let refused = catch_unwind(AssertUnwindSafe(|| server.deploy(Arc::clone(&dep))));
    assert!(refused.is_err(), "3 shards on 2 budgeted workers must be refused");
    server.shutdown();

    // a deployment planned under a different (here: no) budget is also
    // refused when a shard's exact bind footprint exceeds the pool's
    let dcfg = DeployConfig { worker_budget: None, shards: Some(2) };
    let dep = Arc::new(Deployment::build(key, &net.nodes, None, &dcfg).unwrap());
    let cfg = ServeConfig { worker_budget: Some(4096), ..pool_cfg(2, 4) };
    let mut server = Server::start_pool(&cfg);
    let refused = catch_unwind(AssertUnwindSafe(|| server.deploy(Arc::clone(&dep))));
    assert!(refused.is_err(), "shards wider than the pool budget must be refused");
    server.shutdown();
}

#[test]
fn bind_times_returns_a_snapshot_per_worker() {
    // regression for the leaky accessor: bind_times used to hand out
    // the Arc<Mutex<..>> itself; it now returns a plain snapshot, valid
    // to read after shutdown (shutdown no longer consumes the server)
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Uniform(4), 4);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut server = Server::start(Arc::clone(&prepared), &pool_cfg(3, 2));
    for x in inputs {
        server.submit(x);
    }
    let done = server.shutdown();
    assert_eq!(done.len(), 4);
    let binds: Vec<Duration> = server.bind_times();
    assert_eq!(binds.len(), 3, "one eager-bind entry per worker");
    assert!(binds.iter().all(|d| *d > Duration::ZERO));
}

// ---------------------------------------------------------------------
// observability: lifecycle spans, live snapshots, trace export
// ---------------------------------------------------------------------

#[test]
fn completion_spans_are_ordered_and_monotone() {
    // every completion carries its full lifecycle: the marks exist and
    // never run backwards, even with 3 workers racing over the queue
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 24);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let done = serve_all(&prepared, &pool_cfg(3, 4), inputs);
    assert_eq!(done.len(), 24);
    for c in &done {
        let s = &c.spans;
        let closed = s.batch_closed.expect("dispatcher stamps batch close");
        let dispatched = s.dispatched.expect("worker stamps dequeue");
        let bound = s.bound.expect("worker stamps bind");
        let started = s.started.expect("worker stamps start");
        let executed = s.executed.expect("worker stamps finish");
        assert!(s.enqueued <= closed, "request {}", c.id);
        assert!(closed <= dispatched, "request {}", c.id);
        assert!(dispatched <= bound, "request {}", c.id);
        assert!(bound <= started, "request {}", c.id);
        assert!(started <= executed, "request {}", c.id);
        assert_eq!(s.gathered, None, "whole-model completions are never gathered");
        // the derived breakdown telescopes back to enqueue -> executed
        let total = s.queue_wait() + s.bind_wait() + s.batch_wait() + s.service();
        assert_eq!(total, executed.duration_since(s.enqueued), "request {}", c.id);
    }
}

#[test]
fn gathered_completion_spans_carry_the_slowest_shard_finish() {
    let dp = DesignPoint::Patterns(4);
    let net = synthetic_network("tinywide", dp, 3).unwrap();
    let inputs = synthetic_inputs(&net, 4, 5);
    let key = ModelKey::new("tinywide", dp.label());
    let dcfg = DeployConfig { worker_budget: None, shards: Some(2) };
    let dep = Arc::new(Deployment::build(key, &net.nodes, None, &dcfg).unwrap());
    let mut server = Server::start_deployment(Arc::clone(&dep), &pool_cfg(2, 4));
    for x in &inputs {
        server.submit(x.clone());
    }
    let done = server.shutdown();
    assert_eq!(done.len(), inputs.len());
    for c in &done {
        let executed = c.spans.executed.expect("shard 0 executed");
        let gathered = c.spans.gathered.expect("gathered completions carry the gather mark");
        assert!(gathered >= executed, "gather mark is the slowest shard's finish");
    }
    let snap = server.snapshot();
    assert_eq!(snap.gather_outstanding, 0, "every scattered shard was gathered");
    assert_eq!(snap.completed, inputs.len() as u64, "one completion per logical request");
    assert_eq!(snap.submitted, inputs.len() as u64, "shard sub-requests are not re-counted");
}

#[test]
fn snapshot_is_consistent_mid_run_from_another_thread() {
    use std::sync::atomic::{AtomicBool, Ordering};
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 32);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut server = Server::start(Arc::clone(&prepared), &pool_cfg(2, 4));
    let obs = server.obs();
    let stop = Arc::new(AtomicBool::new(false));
    let watcher = {
        let obs = Arc::clone(&obs);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last_completed = 0u64;
            let mut polls = 0u64;
            loop {
                let s = obs.snapshot();
                assert!(s.queue_shared >= 0, "shared queue gauge went negative");
                assert!(s.queue_pinned.iter().all(|&d| d >= 0), "pinned gauge went negative");
                assert!(s.gather_outstanding >= 0, "gather gauge went negative");
                assert!(s.completed <= s.submitted, "completed overtook submitted");
                assert!(s.completed >= last_completed, "completed counter regressed");
                last_completed = s.completed;
                polls += 1;
                if stop.load(Ordering::Relaxed) {
                    return polls;
                }
                std::thread::yield_now();
            }
        })
    };
    for x in inputs {
        server.submit(x);
    }
    let done = server.shutdown();
    stop.store(true, Ordering::Relaxed);
    let polls = watcher.join().expect("mid-run snapshots must stay consistent");
    assert!(polls > 0);
    assert_eq!(done.len(), 32);

    // the post-shutdown snapshot settles to exact totals
    let end = server.snapshot();
    assert_eq!((end.submitted, end.completed), (32, 32));
    assert_eq!(end.queue_shared, 0);
    assert!(end.queue_pinned.iter().all(|&d| d == 0));
    assert_eq!(end.gather_outstanding, 0);
    assert!(end.group_depths.is_empty(), "no group holds depth after the drain");
    assert_eq!(end.workers.iter().map(|w| w.requests).sum::<u64>(), 32);
    assert!(end.workers.iter().map(|w| w.batches).sum::<u64>() >= 8, "32 requests / max batch 4");
    assert_eq!(end.latency_ms.count, 32);
    assert!(end.latency_ms.p50 <= end.latency_ms.p99);
}

#[test]
fn schema4_report_adds_admission_and_open_loop_fields() {
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 16);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut server = Server::start(Arc::clone(&prepared), &pool_cfg(2, 4));
    let t0 = Instant::now();
    for x in inputs {
        server.submit(x);
    }
    let done = server.shutdown();
    let wall = t0.elapsed();
    let snap = server.snapshot();
    let report = summarize_with(&done, wall, SetupTiming::default(), Some(&snap));
    assert_eq!(report.requests, 16);
    assert_eq!(report.workers.len(), 2, "one utilization row per worker");
    assert!(report.binds >= 2, "each worker eager-binds the model");
    assert!(report.service.mean_ms > 0.0);
    assert!(report.queue_wait.mean_ms >= 0.0);
    assert_eq!(report.rejected, 0, "no queue depth configured, nothing shed");
    assert!(report.lost.is_empty() && report.partial.is_empty(), "healthy run loses nothing");

    let parsed = soniq::util::json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("schema").unwrap().as_usize().unwrap(), SERVE_REPORT_SCHEMA as usize);
    // schema 5 keeps kv_pool out of non-paged reports: the key's very
    // presence marks a paged-KV run for grepping tools
    assert!(parsed.get("kv_pool").is_err(), "kv_pool only appears in paged runs");
    for key in ["queue_wait", "bind_wait", "service", "gather_wait"] {
        assert!(parsed.get(&format!("{key}_mean_ms")).is_ok(), "{key} mean in schema 4");
        assert!(parsed.get(&format!("{key}_p99_ms")).is_ok(), "{key} p99 in schema 4");
    }
    assert!(parsed.get("binds").is_ok());
    assert!(parsed.get("evictions").is_ok());
    // schema 4: admission, fault, and open-loop fields
    assert_eq!(parsed.get("rejected").unwrap().as_usize().unwrap(), 0);
    assert!(parsed.get("lost_requests").unwrap().as_arr().unwrap().is_empty());
    assert!(parsed.get("partial_requests").unwrap().as_arr().unwrap().is_empty());
    assert!(parsed.get("open_loop").unwrap().as_arr().unwrap().is_empty());
    let rows = parsed.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        for key in ["worker", "utilization", "busy_ms", "batches", "requests", "binds"] {
            assert!(row.get(key).is_ok(), "worker row carries {key}");
        }
    }
    // summarize without a snapshot (the schema-2 call shape) still
    // works; it just has no worker rows to report
    let plain = summarize(&done, wall, SetupTiming::default());
    assert!(plain.workers.is_empty());
    assert_eq!(plain.binds, 0);
}

#[test]
fn trace_export_is_valid_chrome_trace_json() {
    use soniq::util::json::Json;
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 12);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let cfg = ServeConfig { trace: true, ..pool_cfg(2, 4) };
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    for x in inputs {
        server.submit(x);
    }
    let done = server.shutdown();
    assert_eq!(done.len(), 12);

    let text = server.obs().chrome_trace_json().to_string();
    let parsed = soniq::util::json::parse(&text).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let ph = |e: &Json| e.get("ph").unwrap().as_str().unwrap().to_string();

    // lane metadata: dispatcher + one lane per worker
    let lanes = events.iter().filter(|e| ph(e) == "M").count();
    assert_eq!(lanes, 3);
    // every request opens and closes an async span, paired by id
    let ids = |want: &str| -> HashSet<String> {
        events
            .iter()
            .filter(|e| ph(e) == want)
            .map(|e| e.get("id").unwrap().as_str().unwrap().to_string())
            .collect()
    };
    let begins = ids("b");
    assert_eq!(begins.len(), 12, "one async begin per request");
    assert_eq!(begins, ids("e"), "every request span begin has a matching end");
    // every execution span sits on a worker lane
    let execs: Vec<&Json> = events
        .iter()
        .filter(|e| ph(e) == "X" && e.get("cat").unwrap().as_str().unwrap() == "exec")
        .collect();
    assert_eq!(execs.len(), 12, "one exec span per request");
    for e in &execs {
        let tid = e.get("tid").unwrap().as_usize().unwrap();
        assert!((1..=2).contains(&tid), "exec spans live on worker lanes, got tid {tid}");
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
    }
    let batches = events
        .iter()
        .filter(|e| ph(e) == "X" && e.get("cat").unwrap().as_str().unwrap() == "batch")
        .count();
    assert!(batches >= 3, "12 requests at max batch 4 close at least 3 batch spans");
    // events are globally sorted by timestamp (metadata carries no ts)
    let ts: Vec<f64> = events
        .iter()
        .filter(|e| ph(e) != "M")
        .map(|e| e.get("ts").unwrap().as_f64().unwrap())
        .collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "trace events sorted by ts");
    let snap = server.snapshot();
    assert_eq!(snap.trace_dropped, 0, "a 12-request run fits the lane caps");
}

#[test]
fn batcher_deadline_tracks_oldest_across_arrivals_and_stale_markers() {
    // mid-wait arrivals must not reset the deadline clock, and a
    // size-trigger close must not leave its (stale) FIFO marker
    // shadowing the next live group's deadline
    let cfg = BatchConfig { max_batch: 2, max_delay: Duration::from_millis(5) };
    let mut b = DynamicBatcher::new(cfg);
    let t0 = Instant::now();
    let ha = dummy_handle("a");
    let hb = dummy_handle("b");
    let tok = || Tensor::zeros(1, 1, 1);
    // group a opens at t0; group b arrives mid-wait, 2 ms later
    assert!(b.push(Request::infer(0, &ha, tok(), t0)).is_none());
    assert!(b.push(Request::infer(1, &hb, tok(), t0 + Duration::from_millis(2))).is_none());
    // the deadline is the oldest group's, not the newest arrival's
    assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(5)));
    // closing group a by size leaves a stale marker at the FIFO front;
    // the deadline must skip it and advance to group b
    let full =
        b.push(Request::infer(2, &ha, tok(), t0 + Duration::from_millis(3))).expect("size close");
    assert_eq!(full.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(7)));
    assert!(b.poll_deadline(t0 + Duration::from_millis(6)).is_none());
    let late = b.poll_deadline(t0 + Duration::from_millis(7)).expect("deadline close");
    assert_eq!(late.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    assert!(b.is_empty());
    assert_eq!(b.len(), 0);

    // a re-created group under a previously closed key is live again
    // under a fresh generation
    assert!(b.push(Request::infer(3, &ha, tok(), t0 + Duration::from_millis(8))).is_none());
    assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(13)));
    assert_eq!(b.flush().expect("re-created group flushes").requests[0].id, 3);
    assert!(b.is_empty());
}

#[test]
fn drain_ready_is_consistent_mid_run() {
    // drain_ready interleaved with submissions must hand every
    // completion out exactly once, already final, with the metrics
    // registry agreeing on the totals afterwards
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 32);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let want: Vec<Vec<f32>> =
        inputs.iter().map(|x| run_network(&net.nodes, x).output.data.clone()).collect();
    let mut server = Server::start(Arc::clone(&prepared), &pool_cfg(2, 4));
    let mut done: Vec<Completion> = Vec::new();
    for (i, x) in inputs.iter().enumerate() {
        server.submit(x.clone());
        if i % 5 == 4 {
            done.extend(server.drain_ready());
        }
    }
    let early: HashSet<u64> = done.iter().map(|c| c.id).collect();
    assert_eq!(early.len(), done.len(), "no duplicate completions across drains");
    let rest = server.shutdown();
    assert!(rest.iter().all(|c| !early.contains(&c.id)), "shutdown re-returned drained ids");
    done.extend(rest);
    assert_eq!(done.len(), 32);
    for c in &done {
        assert_eq!(c.output.data, want[c.id as usize], "request {}", c.id);
    }
    let snap = server.snapshot();
    assert_eq!(snap.submitted, 32);
    assert_eq!(snap.completed, 32, "every completion was counted exactly once");
}

#[test]
fn iteration_scheduling_is_bit_exact_across_mixed_lengths_and_admits() {
    // one worker, three sessions of different lengths, one admitted
    // mid-flight after another retired: iteration-level step batches
    // must replay every session bit-identically to a lone engine
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &net.nodes,
        net.step_nodes.as_ref().expect("decoder step graph"),
    ));
    let mut server = Server::start(Arc::clone(&prepared), &pool_cfg(1, 4));
    let tokens: Vec<Vec<Tensor>> =
        (0..3).map(|k| synthetic_step_inputs(&net, k as u64, 6, 21)).collect();
    let s0 = server.open_session();
    let s1 = server.open_session();
    // (request id, session index, step) in submission order
    let mut submitted: Vec<(u64, usize, usize)> = Vec::new();
    for t in 0..2 {
        submitted.push((server.submit_step(s0, tokens[0][t].clone()), 0, t));
        submitted.push((server.submit_step(s1, tokens[1][t].clone()), 1, t));
    }
    // s1 retires after 2 steps; s2 admits mid-flight and interleaves
    // with s0's remaining steps
    server.close_session(s1);
    let s2 = server.open_session();
    for t in 0..4 {
        submitted.push((server.submit_step(s2, tokens[2][t].clone()), 2, t));
        submitted.push((server.submit_step(s0, tokens[0][t + 2].clone()), 0, t + 2));
    }
    server.close_session(s0);
    server.close_session(s2);
    let done = server.shutdown();
    assert!(server.faults().is_none());
    assert_eq!(done.len(), submitted.len(), "closes produce no completions");

    let sids = [s0, s1, s2];
    let mut engine = EngineMachine::new(&prepared);
    let by_id: HashMap<u64, &Completion> = done.iter().map(|c| (c.id, c)).collect();
    for &(id, si, t) in &submitted {
        let want = engine.run_step(si as u64, &tokens[si][t]);
        let got = by_id.get(&id).expect("every submitted step completed");
        assert_eq!(got.session, Some(sids[si].0));
        assert_eq!(got.output.data, want.output.data, "session {si} step {t}");
    }
}

#[test]
fn admission_rejects_at_queue_depth_and_recovers_after_drain() {
    use soniq::serve::Rejected;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 8);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let cfg = ServeConfig { queue_depth: Some(2), ..pool_cfg(1, 4) };
    let mut server = Server::start(Arc::clone(&prepared), &cfg);

    // in-flight depth is submitted minus *drained*, so without a drain
    // the third submission is rejected deterministically
    assert!(server.try_submit(inputs[0].clone()).is_ok());
    assert!(server.try_submit(inputs[1].clone()).is_ok());
    let err = server.try_submit(inputs[2].clone()).unwrap_err();
    assert_eq!(err, Rejected { depth: 2, limit: 2 });
    assert!(err.to_string().contains("queue depth limit 2"), "got: {err}");
    // the plain form treats the bound as hard
    let boom = catch_unwind(AssertUnwindSafe(|| server.submit(inputs[3].clone())));
    assert!(boom.is_err(), "plain submit must panic at the configured depth");
    assert_eq!(server.snapshot().rejected, 2, "both refused submissions were counted");

    // draining completions reopens the gate
    let t0 = Instant::now();
    let mut drained: Vec<Completion> = Vec::new();
    while drained.len() < 2 {
        drained.extend(server.drain_ready());
        assert!(t0.elapsed() < Duration::from_secs(30), "pool stalled with 2 in flight");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(server.try_submit(inputs[2].clone()).is_ok(), "admission recovers after drain");
    let rest = server.shutdown();
    assert_eq!(drained.len() + rest.len(), 3);
    let snap = server.snapshot();
    assert_eq!(snap.rejected, 2, "recovered submissions are not rejections");
    assert_eq!(snap.completed, 3);
}

#[test]
fn dead_worker_losses_are_reported_not_silent() {
    // a shape-mismatched request kills the only worker mid-run; the
    // survivors still come back and the loss is itemized instead of
    // silently shrinking the result set
    let (net, inputs) = net_and_inputs("tinynet", DesignPoint::Patterns(4), 4);
    let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
    let mut server = Server::start(Arc::clone(&prepared), &pool_cfg(1, 1));
    let ok = server.submit(inputs[0].clone());
    let bad = server.submit(Tensor::zeros(1, 1, 1)); // wrong shape for tinynet
    let after = server.submit(inputs[1].clone());
    let done = server.shutdown();
    let faults = server.faults().expect("a dead worker must surface faults");
    assert_eq!(faults.panicked_threads, 1);
    assert!(faults.lost.contains(&bad), "the poisoned request is reported lost");
    assert!(faults.partial.is_empty(), "no sharded traffic, no partial gathers");
    let completed: HashSet<u64> = done.iter().map(|c| c.id).collect();
    for id in [ok, bad, after] {
        assert!(
            completed.contains(&id) || faults.lost.contains(&id),
            "request {id} vanished without completing or being reported lost"
        );
    }
    assert!(!completed.contains(&bad), "the poisoned request cannot have completed");
    // the lost ids flow into the schema-4 report fields
    let mut report = summarize(&done, Duration::from_millis(1), SetupTiming::default());
    report.lost = faults.lost.clone();
    report.partial = faults.partial.clone();
    let parsed = soniq::util::json::parse(&report.to_json().to_string()).unwrap();
    let lost_json = parsed.get("lost_requests").unwrap().as_arr().unwrap().len();
    assert_eq!(lost_json, faults.lost.len());
}

// ---------------------------------------------------------------------
// paged KV-cache: admission, spill round trips, pool reporting
// ---------------------------------------------------------------------

#[test]
fn paged_kv_refuse_gates_admission_and_recovers_on_close() {
    use soniq::serve::{KvPolicy, KvPoolCfg};
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = prepare_any(&net);
    let slots = prepared.step.as_ref().expect("tinydec is a decoder").slot_geoms.len();
    // budget = one page per slot: exactly one stepped session fits
    let kv = KvPoolCfg {
        page_positions: 8,
        pages_per_worker: Some(slots),
        policy: KvPolicy::Refuse,
        v_bits: None,
    };
    let cfg = ServeConfig { kv: Some(kv), ..pool_cfg(1, 4) };
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let tokens = synthetic_step_inputs(&net, 0, 3, 5);

    // opening charges nothing (the first *step* takes the pages), so
    // the first session admits against an empty ledger
    let s0 = server.open_session();
    assert!(server.try_submit_step(s0, tokens[0].clone()).is_ok());
    // the whole budget is now charged to s0: a second session's first
    // step could not take a page, so the open itself is refused
    let err = server.try_open_session().unwrap_err();
    assert_eq!((err.depth, err.limit), (slots, slots));
    // while s0's own steps keep landing inside its already-charged
    // pages (no new page before `page_positions` more positions)
    assert!(server.try_submit_step(s0, tokens[1].clone()).is_ok());
    // close releases exactly the charged pages: admission recovers
    server.close_session(s0);
    let s1 = server.try_open_session().expect("close must release the charged pages");
    assert!(server.try_submit_step(s1, tokens[2].clone()).is_ok());
    server.close_session(s1);

    let done = server.shutdown();
    assert!(server.faults().is_none(), "serving threads died");
    assert_eq!(done.len(), 3, "refused opens shed sessions, never submitted steps");
    let snap = server.snapshot();
    assert!(snap.rejected >= 1, "kv refusals count as shed load");
    let pool = snap.kv_pool.expect("paged run publishes pool state");
    assert_eq!(pool.pages_per_worker, Some(slots));
    assert_eq!(pool.refusals, 1);
    assert_eq!(pool.pages_used, 0, "all sessions closed their pages");
    assert!(pool.pages_free >= slots, "closed pages sit on the free list for reuse");
    assert_eq!((pool.spills, pool.faults, pool.evictions), (0, 0, 0));
}

#[test]
fn paged_kv_spill_round_trips_sessions_bit_exactly_under_pressure() {
    use soniq::serve::{KvPolicy, KvPoolCfg};
    let net = synthetic_network("tinydec", DesignPoint::Patterns(4), 3).unwrap();
    let prepared = prepare_any(&net);
    let slots = prepared.step.as_ref().expect("tinydec is a decoder").slot_geoms.len();
    // a one-session budget with three interleaved sessions: every step
    // faults its session back in and spills the previous one out
    let kv = KvPoolCfg {
        page_positions: 4,
        pages_per_worker: Some(slots),
        policy: KvPolicy::Spill,
        v_bits: None,
    };
    let cfg = ServeConfig { kv: Some(kv), ..pool_cfg(1, 4) };
    let mut server = Server::start(Arc::clone(&prepared), &cfg);
    let n_sessions = 3usize;
    let steps = 3usize;
    let tokens: Vec<Vec<Tensor>> = (0..n_sessions)
        .map(|s| synthetic_step_inputs(&net, s as u64, steps, 5))
        .collect();
    let sids: Vec<SessionId> = (0..n_sessions).map(|_| server.open_session()).collect();
    let mut ids: Vec<(u64, usize, usize)> = Vec::new();
    for t in 0..steps {
        for (si, sid) in sids.iter().enumerate() {
            ids.push((server.submit_step(*sid, tokens[si][t].clone()), si, t));
        }
    }
    for sid in &sids {
        server.close_session(*sid);
    }
    let done = server.shutdown();
    assert!(server.faults().is_none(), "serving threads died");
    assert_eq!(done.len(), n_sessions * steps);

    // spilled-and-faulted decode must match a lone growable engine
    // bit-for-bit — the round trip moves pages verbatim
    let by_id: HashMap<u64, &Completion> = done.iter().map(|c| (c.id, c)).collect();
    let mut engine = EngineMachine::new(&prepared);
    for &(id, si, t) in &ids {
        let want = engine.run_step(si as u64, &tokens[si][t]);
        assert_eq!(
            by_id[&id].output.data, want.output.data,
            "session {si} step {t} diverged through the spill arena"
        );
    }

    let snap = server.snapshot();
    let pool = snap.kv_pool.expect("paged run publishes pool state");
    assert!(pool.spills >= 1 && pool.faults >= 1, "pressure must spill and fault back");
    assert_eq!(pool.evictions, 0, "spill parks pages, it never drops them");
    assert_eq!(pool.refusals, 0, "spill admits everything");
    assert_eq!((pool.pages_used, pool.spilled_pages), (0, 0), "closed sessions free the pool");

    // the pool block lands in the schema-5 report JSON, and worker
    // rows carry the resident page gauge
    let report =
        summarize_with(&done, Duration::from_millis(1), SetupTiming::default(), Some(&snap));
    let parsed = soniq::util::json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("schema").unwrap().as_usize().unwrap(), SERVE_REPORT_SCHEMA as usize);
    let kvp = parsed.get("kv_pool").unwrap();
    assert_eq!(kvp.get("pages_per_worker").unwrap().as_usize().unwrap(), slots);
    assert_eq!(kvp.get("spills").unwrap().as_usize().unwrap() as u64, pool.spills);
    assert_eq!(kvp.get("faults").unwrap().as_usize().unwrap() as u64, pool.faults);
    assert_eq!(kvp.get("refusals").unwrap().as_usize().unwrap(), 0);
    let rows = parsed.get("workers").unwrap().as_arr().unwrap();
    assert!(rows.iter().all(|r| r.get("kv_pages").is_ok()), "worker rows carry kv_pages");
}
