//! Property-based tests (seeded sweeps via util::prop — the offline
//! substitute for proptest) over the coordinator-side invariants:
//! quantization, packing, the ALU datapath, Problem-1 coverage, pattern
//! matching, and the code generator vs. a direct reference.

use soniq::codegen::{self, Counter, DataFormat, LayerBufs, LayerKind, LayerPlan};
use soniq::simd::alu;
use soniq::simd::isa::BufId;
use soniq::simd::patterns::{all_patterns, design_subset, Pattern};
use soniq::simd::vector::{pack_values, unpack_values};
use soniq::smol::pattern_match::{demand_from_s, pattern_match};
use soniq::smol::problem1::solve;
use soniq::smol::quant;
use soniq::util::prop::check;
use soniq::util::rng::Rng;

fn rand_precision(rng: &mut Rng) -> u8 {
    *rng.choice(&[1u8, 2, 4])
}

fn rand_qvalue(rng: &mut Rng, p: u8) -> f32 {
    quant::code_to_value(rng.below(1 << p) as u32, p)
}

#[test]
fn prop_quantize_idempotent_bounded_odd() {
    check("quantize", 3000, |rng| {
        let p = rand_precision(rng);
        let x = rng.range(-5.0, 5.0);
        let q = quant::quantize(x, p);
        if quant::quantize(q, p) != q {
            return Err(format!("not idempotent: p={p} x={x} q={q}"));
        }
        if q.abs() > quant::qmax_for(p) || q.abs() < quant::step_for(p) {
            return Err(format!("out of range: p={p} q={q}"));
        }
        let m = (q / quant::step_for(p)) as i64;
        if m % 2 == 0 {
            return Err(format!("even mantissa: p={p} q={q}"));
        }
        // within clip range the error is at most one step
        if x.abs() <= quant::qmax_for(p) && (q - x).abs() > quant::step_for(p) + 1e-6 {
            return Err(format!("error too large: p={p} x={x} q={q}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    let pats = all_patterns();
    check("pack-roundtrip", 500, |rng| {
        let pat = *rng.choice(&pats);
        let vals: Vec<f32> = (0..pat.capacity())
            .map(|i| rand_qvalue(rng, pat.element_precision(i)))
            .collect();
        let v = pack_values(&pat, &vals);
        let back = unpack_values(&pat, &v);
        if back != vals {
            return Err(format!("roundtrip mismatch for {pat:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_vmac_equals_float_dot() {
    let pats = all_patterns();
    check("vmac-dot", 400, |rng| {
        let pat = *rng.choice(&pats);
        let a: Vec<f32> = (0..pat.capacity())
            .map(|i| rand_qvalue(rng, pat.element_precision(i)))
            .collect();
        let b: Vec<f32> = (0..pat.capacity())
            .map(|i| rand_qvalue(rng, pat.element_precision(i)))
            .collect();
        let va = pack_values(&pat, &a);
        let vb = pack_values(&pat, &b);
        let got = alu::reduce_acc(&alu::vmac(&va, &vb, &pat)) as f32 / 64.0;
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        if got != want {
            return Err(format!("{pat:?}: {got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_vmul_decode_recovers_products() {
    check("vmul-decode", 500, |rng| {
        let p = rand_precision(rng);
        let pat = Pattern::uniform(p);
        let a: Vec<f32> = (0..pat.capacity()).map(|_| rand_qvalue(rng, p)).collect();
        let b: Vec<f32> = (0..pat.capacity()).map(|_| rand_qvalue(rng, p)).collect();
        let va = pack_values(&pat, &a);
        let vb = pack_values(&pat, &b);
        let (lo, hi) = alu::vmul(&va, &vb, &pat);
        let unit = quant::step_for(p) * quant::step_for(p);
        let per_lane = 16 / p as usize;
        for lane in 0..8usize {
            let prods = alu::decode_mul_lane(lo.lanes[lane], hi.lanes[lane], p);
            for (k, prod) in prods.iter().enumerate() {
                let e = lane * per_lane + k;
                let want = a[e] * b[e];
                if *prod as f32 * unit != want {
                    return Err(format!("p={p} lane={lane} k={k}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_problem1_coverage_and_minimality() {
    check("problem1", 200, |rng| {
        let np = *rng.choice(&[4usize, 8, 45]);
        let pats = design_subset(np);
        let s: Vec<f32> = (0..(8 + rng.below(200) as usize))
            .map(|_| rng.range(-4.0, 8.0))
            .collect();
        let d = demand_from_s(&s);
        let c = solve(&d, &pats).ok_or("no solution")?;
        if c.slots(4) < d.n4 {
            return Err(format!("4-bit coverage violated: {c:?} vs {d:?}"));
        }
        if c.slots(4) + c.slots(2) < d.n4 + d.n2 {
            return Err(format!("2-bit coverage violated"));
        }
        if c.capacity() < d.total() {
            return Err(format!("total coverage violated"));
        }
        // minimality: removing any one chunk must break a constraint
        if !c.chunks.is_empty() {
            for drop in 0..c.chunks.len() {
                let mut rest: Vec<Pattern> = c.chunks.clone();
                rest.remove(drop);
                let s4: u32 = rest.iter().map(|p| p.count(4)).sum();
                let s24: u32 = rest.iter().map(|p| p.count(4) + p.count(2)).sum();
                let cap: u32 = rest.iter().map(|p| p.capacity()).sum();
                if s4 >= d.n4 && s24 >= d.n4 + d.n2 && cap >= d.total() {
                    return Err(format!("solution not minimal: chunk {drop} removable"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pattern_match_is_permutation_and_monotone() {
    check("pattern-match", 200, |rng| {
        let np = *rng.choice(&[4usize, 8, 45]);
        let n = 4 + rng.below(150) as usize;
        let s: Vec<f32> = (0..n).map(|_| rng.range(-4.0, 8.0)).collect();
        let a = pattern_match(&s, &design_subset(np));
        // permutation
        let mut seen = vec![false; n];
        for &ch in &a.order {
            if seen[ch as usize] {
                return Err(format!("duplicate channel {ch}"));
            }
            seen[ch as usize] = true;
        }
        if !seen.iter().all(|&b| b) {
            return Err("missing channel".into());
        }
        // monotone: if s_i <= s_j (i more important) then prec_i >= prec_j
        for i in 0..n {
            for j in 0..n {
                if s[i] < s[j] && a.precision[i] < a.precision[j] {
                    return Err(format!(
                        "importance violated: s[{i}]={} < s[{j}]={} but {} < {}",
                        s[i], s[j], a.precision[i], a.precision[j]
                    ));
                }
            }
        }
        // layout consistency
        let total_valid: u32 = a.valid.iter().sum();
        if total_valid != n as u32 {
            return Err(format!("valid {total_valid} != channels {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_codegen_instruction_count_scales_with_chunks() {
    check("codegen-scaling", 60, |rng| {
        let cin = 16 + rng.below(120) as usize;
        let hw = 3 + rng.below(6) as usize;
        let cout = 1 + rng.below(6) as usize;
        let bufs = LayerBufs {
            input: BufId(0),
            weights: BufId(1),
            out: BufId(2),
            masks: BufId(3),
        };
        let mk = |bits: u8| LayerPlan {
            name: "t".into(),
            kind: LayerKind::Dense,
            cin,
            cout,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: hw,
            win: hw,
            asg: soniq::smol::pattern_match::Assignment::uniform(cin, bits),
            fmt: DataFormat::Smol,
        };
        let count = |plan: &LayerPlan| {
            let mut c = Counter::default();
            codegen::emit_layer(plan, &bufs, 0, &mut c);
            c
        };
        let c4 = count(&mk(4));
        let c1 = count(&mk(1));
        // vmac count proportional to chunk count
        let chunks4 = cin.div_ceil(32) as u64;
        let chunks1 = cin.div_ceil(128) as u64;
        if c4.vmac * chunks1 != c1.vmac * chunks4 {
            return Err(format!(
                "vmac not proportional: {}*{} != {}*{}",
                c4.vmac, chunks1, c1.vmac, chunks4
            ));
        }
        // stores = out elements per chunk sweep
        if c4.stores != (cout * hw * hw) as u64 * chunks4 {
            return Err(format!("store count {}", c4.stores));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use soniq::util::json::{parse, Json};
    check("json-roundtrip", 300, |rng| {
        // generate a random value tree
        fn gen(rng: &mut Rng, depth: u32) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 1),
                2 => Json::Num((rng.below(2_000_000) as f64 - 1e6) / 64.0),
                3 => {
                    let n = rng.below(10) as usize;
                    Json::Str((0..n).map(|_| *rng.choice(&['a', 'é', '"', '\\', '\n', 'z'])).collect())
                }
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..rng.below(5) {
                        m.insert(format!("k{i}"), gen(rng, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = parse(&text).map_err(|e| format!("parse failed: {e} on {text}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}
