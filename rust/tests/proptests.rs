//! Property-based tests (seeded sweeps via util::prop — the offline
//! substitute for proptest) over the coordinator-side invariants:
//! quantization, packing, the ALU datapath, Problem-1 coverage, pattern
//! matching, and the code generator vs. a direct reference.

use soniq::codegen::gemm::GemmPlan;
use soniq::codegen::{self, Counter, DataFormat, LayerBufs, LayerKind, LayerPlan};
use soniq::serve::{BoundKernel, ExecCtx, PreparedMatmul, PreparedOp, WorkerScratch};
use soniq::sim::eltwise;
use soniq::sim::machine::Machine;
use soniq::sim::network::{MatmulCfg, Tensor};
use soniq::simd::alu;
use soniq::simd::isa::BufId;
use soniq::simd::patterns::{all_patterns, design_subset, Pattern};
use soniq::simd::vector::{pack_values, unpack_values};
use soniq::smol::pattern_match::{demand_from_s, pattern_match, Assignment};
use soniq::smol::problem1::solve;
use soniq::smol::quant;
use soniq::util::prop::check;
use soniq::util::rng::Rng;

fn rand_precision(rng: &mut Rng) -> u8 {
    *rng.choice(&[1u8, 2, 4])
}

fn rand_qvalue(rng: &mut Rng, p: u8) -> f32 {
    quant::code_to_value(rng.below(1 << p) as u32, p)
}

#[test]
fn prop_quantize_idempotent_bounded_odd() {
    check("quantize", 3000, |rng| {
        let p = rand_precision(rng);
        let x = rng.range(-5.0, 5.0);
        let q = quant::quantize(x, p);
        if quant::quantize(q, p) != q {
            return Err(format!("not idempotent: p={p} x={x} q={q}"));
        }
        if q.abs() > quant::qmax_for(p) || q.abs() < quant::step_for(p) {
            return Err(format!("out of range: p={p} q={q}"));
        }
        let m = (q / quant::step_for(p)) as i64;
        if m % 2 == 0 {
            return Err(format!("even mantissa: p={p} q={q}"));
        }
        // within clip range the error is at most one step
        if x.abs() <= quant::qmax_for(p) && (q - x).abs() > quant::step_for(p) + 1e-6 {
            return Err(format!("error too large: p={p} x={x} q={q}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    let pats = all_patterns();
    check("pack-roundtrip", 500, |rng| {
        let pat = *rng.choice(&pats);
        let vals: Vec<f32> = (0..pat.capacity())
            .map(|i| rand_qvalue(rng, pat.element_precision(i)))
            .collect();
        let v = pack_values(&pat, &vals);
        let back = unpack_values(&pat, &v);
        if back != vals {
            return Err(format!("roundtrip mismatch for {pat:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_vmac_equals_float_dot() {
    let pats = all_patterns();
    check("vmac-dot", 400, |rng| {
        let pat = *rng.choice(&pats);
        let a: Vec<f32> = (0..pat.capacity())
            .map(|i| rand_qvalue(rng, pat.element_precision(i)))
            .collect();
        let b: Vec<f32> = (0..pat.capacity())
            .map(|i| rand_qvalue(rng, pat.element_precision(i)))
            .collect();
        let va = pack_values(&pat, &a);
        let vb = pack_values(&pat, &b);
        let got = alu::reduce_acc(&alu::vmac(&va, &vb, &pat)) as f32 / 64.0;
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        if got != want {
            return Err(format!("{pat:?}: {got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_vmul_decode_recovers_products() {
    check("vmul-decode", 500, |rng| {
        let p = rand_precision(rng);
        let pat = Pattern::uniform(p);
        let a: Vec<f32> = (0..pat.capacity()).map(|_| rand_qvalue(rng, p)).collect();
        let b: Vec<f32> = (0..pat.capacity()).map(|_| rand_qvalue(rng, p)).collect();
        let va = pack_values(&pat, &a);
        let vb = pack_values(&pat, &b);
        let (lo, hi) = alu::vmul(&va, &vb, &pat);
        let unit = quant::step_for(p) * quant::step_for(p);
        let per_lane = 16 / p as usize;
        for lane in 0..8usize {
            let prods = alu::decode_mul_lane(lo.lanes[lane], hi.lanes[lane], p);
            for (k, prod) in prods.iter().enumerate() {
                let e = lane * per_lane + k;
                let want = a[e] * b[e];
                if *prod as f32 * unit != want {
                    return Err(format!("p={p} lane={lane} k={k}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_pack_matches_full_pack_slice() {
    // >= 200 cases across precisions (uniform 1/2/4 and PatternMatch
    // mixes under the P4/P8 subsets): packing a cout sub-range through
    // the shard-scoped plan is bit-identical to the corresponding byte
    // slice of the full-model pack — for conv kernels and for the GEMM
    // layer_plan view (slice_n + column-sliced [k][n] operand)
    check("shard-pack", 300, |rng| {
        let cin = 1 + rng.below(48) as usize;
        let cout = 2 + rng.below(24) as usize;
        let kk = *rng.choice(&[1usize, 3]);
        let asg = match rng.below(5) {
            0 => Assignment::uniform(cin, 1),
            1 => Assignment::uniform(cin, 2),
            2 => Assignment::uniform(cin, 4),
            n => {
                let s: Vec<f32> = (0..cin).map(|_| rng.range(-3.0, 6.0)).collect();
                pattern_match(&s, &design_subset(if n == 3 { 4 } else { 8 }))
            }
        };
        let plan = LayerPlan {
            name: "shardpack".into(),
            kind: LayerKind::Dense,
            cin,
            cout,
            kh: kk,
            kw: kk,
            stride: 1,
            hin: 2,
            win: 2,
            asg,
            fmt: DataFormat::Smol,
        };
        let w: Vec<f32> = (0..kk * kk * cin * cout).map(|_| rng.range(-1.1, 1.1)).collect();
        let full = codegen::pack::pack_weights(&plan, &w);
        let row = codegen::pack::packed_cout_row_bytes(&plan);
        if full.len() != cout * row {
            return Err(format!("pack len {} != cout {cout} * row {row}", full.len()));
        }
        let start = rng.below(cout as u64 - 1) as usize;
        let end = start + 1 + rng.below((cout - start) as u64) as usize;
        let shard = codegen::shard::pack_weights_cout_range(&plan, &w, start, end);
        if shard[..] != full[start * row..end * row] {
            return Err(format!("cout [{start}, {end}) of {cout}: shard pack diverged"));
        }

        let gp = GemmPlan {
            name: "g".into(),
            m: 3,
            k: cin,
            n: cout,
            asg: plan.asg.clone(),
            fmt: DataFormat::Smol,
        };
        let gw: Vec<f32> = (0..cin * cout).map(|_| rng.range(-0.9, 0.9)).collect();
        let gfull = codegen::pack::pack_weights(&gp.layer_plan(), &gw);
        let grow = codegen::pack::packed_cout_row_bytes(&gp.layer_plan());
        let gshard = codegen::pack::pack_weights(
            &gp.slice_n(start, end).layer_plan(),
            &codegen::shard::slice_gemm_weights_n(cin, cout, &gw, start, end),
        );
        if gshard[..] != gfull[start * grow..end * grow] {
            return Err(format!("gemm n slice [{start}, {end}) pack diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_problem1_coverage_and_minimality() {
    check("problem1", 200, |rng| {
        let np = *rng.choice(&[4usize, 8, 45]);
        let pats = design_subset(np);
        let s: Vec<f32> = (0..(8 + rng.below(200) as usize))
            .map(|_| rng.range(-4.0, 8.0))
            .collect();
        let d = demand_from_s(&s);
        let c = solve(&d, &pats).ok_or("no solution")?;
        if c.slots(4) < d.n4 {
            return Err(format!("4-bit coverage violated: {c:?} vs {d:?}"));
        }
        if c.slots(4) + c.slots(2) < d.n4 + d.n2 {
            return Err(format!("2-bit coverage violated"));
        }
        if c.capacity() < d.total() {
            return Err(format!("total coverage violated"));
        }
        // minimality: removing any one chunk must break a constraint
        if !c.chunks.is_empty() {
            for drop in 0..c.chunks.len() {
                let mut rest: Vec<Pattern> = c.chunks.clone();
                rest.remove(drop);
                let s4: u32 = rest.iter().map(|p| p.count(4)).sum();
                let s24: u32 = rest.iter().map(|p| p.count(4) + p.count(2)).sum();
                let cap: u32 = rest.iter().map(|p| p.capacity()).sum();
                if s4 >= d.n4 && s24 >= d.n4 + d.n2 && cap >= d.total() {
                    return Err(format!("solution not minimal: chunk {drop} removable"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pattern_match_is_permutation_and_monotone() {
    check("pattern-match", 200, |rng| {
        let np = *rng.choice(&[4usize, 8, 45]);
        let n = 4 + rng.below(150) as usize;
        let s: Vec<f32> = (0..n).map(|_| rng.range(-4.0, 8.0)).collect();
        let a = pattern_match(&s, &design_subset(np));
        // permutation
        let mut seen = vec![false; n];
        for &ch in &a.order {
            if seen[ch as usize] {
                return Err(format!("duplicate channel {ch}"));
            }
            seen[ch as usize] = true;
        }
        if !seen.iter().all(|&b| b) {
            return Err("missing channel".into());
        }
        // monotone: if s_i <= s_j (i more important) then prec_i >= prec_j
        for i in 0..n {
            for j in 0..n {
                if s[i] < s[j] && a.precision[i] < a.precision[j] {
                    return Err(format!(
                        "importance violated: s[{i}]={} < s[{j}]={} but {} < {}",
                        s[i], s[j], a.precision[i], a.precision[j]
                    ));
                }
            }
        }
        // layout consistency
        let total_valid: u32 = a.valid.iter().sum();
        if total_valid != n as u32 {
            return Err(format!("valid {total_valid} != channels {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_codegen_instruction_count_scales_with_chunks() {
    check("codegen-scaling", 60, |rng| {
        let cin = 16 + rng.below(120) as usize;
        let hw = 3 + rng.below(6) as usize;
        let cout = 1 + rng.below(6) as usize;
        let bufs = LayerBufs {
            input: BufId(0),
            weights: BufId(1),
            out: BufId(2),
            masks: BufId(3),
        };
        let mk = |bits: u8| LayerPlan {
            name: "t".into(),
            kind: LayerKind::Dense,
            cin,
            cout,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: hw,
            win: hw,
            asg: soniq::smol::pattern_match::Assignment::uniform(cin, bits),
            fmt: DataFormat::Smol,
        };
        let count = |plan: &LayerPlan| {
            let mut c = Counter::default();
            codegen::emit_layer(plan, &bufs, 0, &mut c);
            c
        };
        let c4 = count(&mk(4));
        let c1 = count(&mk(1));
        // vmac count proportional to chunk count
        let chunks4 = cin.div_ceil(32) as u64;
        let chunks1 = cin.div_ceil(128) as u64;
        if c4.vmac * chunks1 != c1.vmac * chunks4 {
            return Err(format!(
                "vmac not proportional: {}*{} != {}*{}",
                c4.vmac, chunks1, c1.vmac, chunks4
            ));
        }
        // stores = out elements per chunk sweep
        if c4.stores != (cout * hw * hw) as u64 * chunks4 {
            return Err(format!("store count {}", c4.stores));
        }
        Ok(())
    });
}

/// Random per-channel assignment over `ch` channels: uniform precision
/// or PatternMatch on random sensitivities under a random design subset.
fn rand_assignment(rng: &mut Rng, ch: usize) -> Assignment {
    if rng.below(3) == 0 {
        Assignment::uniform(ch, rand_precision(rng))
    } else {
        let np = *rng.choice(&[4usize, 8, 45]);
        let s: Vec<f32> = (0..ch).map(|_| rng.range(-4.0, 8.0)).collect();
        pattern_match(&s, &design_subset(np))
    }
}

fn rand_seq_tensor(rng: &mut Rng, h: usize, w: usize, c: usize, lo: f32, hi: f32) -> Tensor {
    let data: Vec<f32> = (0..h * w * c).map(|_| rng.range(lo, hi)).collect();
    Tensor { h, w, c, data }
}

/// Run a prepared GEMM op against a bound machine through the trait API.
fn run_mm(
    machine: &mut Machine,
    op: &PreparedMatmul,
    bound: &BoundKernel,
    inputs: &[&Tensor],
    scratch: &mut WorkerScratch,
) -> Tensor {
    let mut ctx = ExecCtx {
        m: &mut *machine,
        bound: Some(bound),
        scratch: &mut *scratch,
        session: None,
        kv: None,
    };
    op.run(&mut ctx, inputs)
}

/// Plain f64 GEMM oracle (the `ref_conv` of the Transformer path): both
/// operands quantized per contraction channel, exact dyadic products
/// summed in f64, then the engine's f32 scale. `b(head, kk, j)` indexes
/// the effective `[k][n]` right operand.
fn ref_gemm<F: Fn(usize, usize, usize) -> f32>(
    plan: &GemmPlan,
    scale: f32,
    heads: usize,
    a: &Tensor,
    b: F,
) -> Tensor {
    let (m, k, n) = (plan.m, plan.k, plan.n);
    let mut out = Tensor::zeros(heads, m, n);
    for h in 0..heads {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    let p = plan.asg.precision[kk];
                    let av = quant::quantize(a.at(h, i, kk), p);
                    let bv = quant::quantize(b(h, kk, j), p);
                    acc += av as f64 * bv as f64;
                }
                out.data[(h * m + i) * n + j] = acc as f32 * scale;
            }
        }
    }
    out
}

/// The ISSUE-2 oracle sweep: the GEMM emitter (static and dynamic
/// operands, including the engine's row-blocked kernel, tail masking and
/// tail-bias epilogue) must match a plain f64 oracle *exactly*, and the
/// softmax/layernorm/GELU epilogues must match f64 references to f32
/// tolerance — across random {seq_len, d_model(=heads*dh), heads,
/// precision pattern}.
#[test]
fn prop_gemm_and_attention_epilogues_match_oracle() {
    check("gemm-attn-oracle", 500, |rng| {
        let fmt = DataFormat::Smol;
        let mut scratch = WorkerScratch::default();

        // --- static-operand GEMM (projection / FFN shape) ---
        let m = 1 + rng.below(5) as usize;
        let n = 1 + rng.below(5) as usize;
        let k = 1 + rng.below(40) as usize;
        let scale = *rng.choice(&[1.0f32, 0.35]);
        let cfg = MatmulCfg {
            plan: GemmPlan { name: "g".into(), m, k, n, asg: rand_assignment(rng, k), fmt },
            scale,
            causal: false,
        };
        let a = rand_seq_tensor(rng, 1, m, k, -2.0, 2.0);
        let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.5, 1.5)).collect();
        let prep = PreparedMatmul::prepare_static(&cfg, &b);
        let mut machine = Machine::new();
        let bound = prep.bind(&mut machine).expect("gemm binds");
        let got = run_mm(&mut machine, &prep, &bound, &[&a], &mut scratch);
        let stats = machine.take_stats();
        let want = ref_gemm(&cfg.plan, scale, 1, &a, |_, kk, j| b[kk * n + j]);
        if got.data != want.data {
            return Err(format!("static gemm mismatch (m={m} k={k} n={n})"));
        }
        if stats.vmac == 0 || stats.cycles() == 0 {
            return Err("static gemm ran no MACs".into());
        }

        // --- dynamic-operand attention core: QK^T -> softmax -> A·V ---
        let heads = *rng.choice(&[1usize, 2]);
        let dh = *rng.choice(&[2usize, 4]);
        let s = 2 + rng.below(4) as usize;
        let q = rand_seq_tensor(rng, heads, s, dh, -2.0, 2.0);
        let kx = rand_seq_tensor(rng, heads, s, dh, -2.0, 2.0);
        let vx = rand_seq_tensor(rng, heads, s, dh, -1.5, 1.5);
        let qk_cfg = MatmulCfg {
            plan: GemmPlan {
                name: "qk".into(),
                m: s,
                k: dh,
                n: s,
                asg: rand_assignment(rng, dh),
                fmt,
            },
            scale: 1.0 / (dh as f32).sqrt(),
            causal: false,
        };
        let av_cfg = MatmulCfg {
            plan: GemmPlan {
                name: "av".into(),
                m: s,
                k: s,
                n: dh,
                asg: rand_assignment(rng, s),
                fmt,
            },
            scale: 1.0,
            causal: false,
        };
        let qk_prep = PreparedMatmul::prepare_dyn(&qk_cfg, true);
        let av_prep = PreparedMatmul::prepare_dyn(&av_cfg, false);
        let qk_bound = qk_prep.bind(&mut machine).expect("qk binds");
        let av_bound = av_prep.bind(&mut machine).expect("av binds");

        // QK^T (transpose_b): contracts channels with channels
        let mut scores = run_mm(&mut machine, &qk_prep, &qk_bound, &[&q, &kx], &mut scratch);
        let want_scores =
            ref_gemm(&qk_cfg.plan, qk_cfg.scale, heads, &q, |h, kk, j| kx.at(h, j, kk));
        if scores.data != want_scores.data {
            return Err(format!("QK^T mismatch (heads={heads} s={s} dh={dh})"));
        }

        // the engine's own f32 softmax keeps the chain exact end-to-end
        eltwise::softmax_rows(&mut scores.data, scores.c);

        // A·V: contracts A's channels with V's sequence axis
        let ctx = run_mm(&mut machine, &av_prep, &av_bound, &[&scores, &vx], &mut scratch);
        let want_ctx = ref_gemm(&av_cfg.plan, 1.0, heads, &scores, |h, kk, j| vx.at(h, kk, j));
        if ctx.data != want_ctx.data {
            return Err(format!("A*V mismatch (heads={heads} s={s} dh={dh})"));
        }

        // --- element-wise epilogues vs plain f64 references ---
        let row = 1 + rng.below(12) as usize;
        let rows = 1 + rng.below(4) as usize;
        let vals: Vec<f32> = (0..rows * row).map(|_| rng.range(-4.0, 4.0)).collect();

        let mut sm = vals.clone();
        eltwise::softmax_rows(&mut sm, row);
        for (r, chunk) in vals.chunks(row).enumerate() {
            let max = chunk.iter().copied().fold(f64::NEG_INFINITY, |x, v| x.max(v as f64));
            let exps: Vec<f64> = chunk.iter().map(|&v| (v as f64 - max).exp()).collect();
            let sum: f64 = exps.iter().sum();
            for (c, e) in exps.iter().enumerate() {
                let diff = (sm[r * row + c] as f64 - e / sum).abs();
                if diff > 1e-5 {
                    return Err(format!("softmax off f64 oracle by {diff}"));
                }
            }
        }

        let gamma: Vec<f32> = (0..row).map(|_| rng.range(0.5, 1.5)).collect();
        let beta: Vec<f32> = (0..row).map(|_| rng.range(-0.5, 0.5)).collect();
        let mut ln = vals.clone();
        eltwise::layernorm_rows(&mut ln, row, &gamma, &beta);
        for (r, chunk) in vals.chunks(row).enumerate() {
            let mean = chunk.iter().map(|&v| v as f64).sum::<f64>() / row as f64;
            let var = chunk.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>()
                / row as f64;
            let inv = 1.0 / (var + eltwise::LN_EPS as f64).sqrt();
            for (c, &v) in chunk.iter().enumerate() {
                let want = (v as f64 - mean) * inv * gamma[c] as f64 + beta[c] as f64;
                let diff = (ln[r * row + c] as f64 - want).abs();
                // near-degenerate rows amplify f32 cancellation by `inv`
                if diff > 1e-5 + 4e-6 * inv {
                    return Err(format!("layernorm off f64 oracle by {diff}"));
                }
            }
        }

        let mut ge = vals.clone();
        eltwise::gelu_rows(&mut ge);
        let c = (2.0 / std::f64::consts::PI).sqrt();
        for (i, &v) in vals.iter().enumerate() {
            let x = v as f64;
            let want = 0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh());
            if (ge[i] as f64 - want).abs() > 1e-5 {
                return Err(format!("gelu off f64 oracle at x={x}"));
            }
        }

        Ok(())
    });
}

/// The causal-mask score GEMM vs the f64 oracle: the lower triangle
/// (including the diagonal) must match the plain quantized dot product
/// exactly, the upper triangle must be `-inf`, and softmax over the
/// masked rows must put exactly zero probability on future positions.
#[test]
fn prop_causal_score_gemm_matches_oracle() {
    check("causal-qk-oracle", 300, |rng| {
        let fmt = DataFormat::Smol;
        let mut scratch = WorkerScratch::default();
        let heads = *rng.choice(&[1usize, 2]);
        let dh = *rng.choice(&[2usize, 4, 8]);
        let s = 2 + rng.below(10) as usize;
        let q = rand_seq_tensor(rng, heads, s, dh, -2.0, 2.0);
        let kx = rand_seq_tensor(rng, heads, s, dh, -2.0, 2.0);
        let cfg = MatmulCfg {
            plan: GemmPlan {
                name: "cqk".into(),
                m: s,
                k: dh,
                n: s,
                asg: rand_assignment(rng, dh),
                fmt,
            },
            scale: 1.0 / (dh as f32).sqrt(),
            causal: true,
        };
        let prep = PreparedMatmul::prepare_dyn(&cfg, true);
        let mut machine = Machine::new();
        let bound = prep.bind(&mut machine).expect("causal qk binds");
        let got = run_mm(&mut machine, &prep, &bound, &[&q, &kx], &mut scratch);
        let want = ref_gemm(&cfg.plan, cfg.scale, heads, &q, |h, kk, j| kx.at(h, j, kk));
        for h in 0..heads {
            for i in 0..s {
                for j in 0..s {
                    let g = got.data[(h * s + i) * s + j];
                    if j > i {
                        if g != f32::NEG_INFINITY {
                            return Err(format!("causal mask leak at ({i},{j}): {g}"));
                        }
                    } else if g != want.data[(h * s + i) * s + j] {
                        return Err(format!("causal score mismatch at ({h},{i},{j})"));
                    }
                }
            }
        }
        // softmax over masked rows: finite, normalized, zero on future
        let mut sm = got.data.clone();
        eltwise::softmax_rows(&mut sm, s);
        for (ri, row) in sm.chunks(s).enumerate() {
            let i = ri % s;
            let sum: f32 = row.iter().sum();
            if (sum - 1.0).abs() > 1e-5 {
                return Err(format!("masked softmax row {ri} sums to {sum}"));
            }
            if row[i + 1..].iter().any(|&p| p != 0.0) {
                return Err(format!("future position has probability in row {ri}"));
            }
        }
        Ok(())
    });
}

/// The ISSUE-3 decode contract: sweeping random `{prefix_len, steps,
/// heads, precision pattern}`, every KV-cached decode step must be
/// bit-identical to re-running its full token prefix through the
/// one-shot causal graph (same weights, rebuilt at the prefix length).
#[test]
fn prop_cached_decode_bit_identical_to_prefix_rerun() {
    use soniq::coordinator::{synthetic_decoder, DecoderCfg, DesignPoint};
    use soniq::serve::{EngineMachine, PreparedModel};
    use soniq::sim::network::run_network;
    use std::sync::Arc;
    check("cached-decode", 200, |rng| {
        let heads = *rng.choice(&[1usize, 2, 4]);
        let dh = *rng.choice(&[2usize, 4]);
        let d = heads * dh;
        let dp = match rng.below(4) {
            0 => DesignPoint::Uniform(2),
            1 => DesignPoint::Uniform(4),
            2 => DesignPoint::Patterns(8),
            _ => DesignPoint::Patterns(45),
        };
        let prefix = 1 + rng.below(4) as usize;
        let steps = 1 + rng.below(3) as usize;
        let total = prefix + steps;
        let cfg = DecoderCfg {
            seq: total,
            d_model: d,
            heads,
            ffn: d * 2,
            blocks: 1,
            max_positions: 16,
        };
        let seed = rng.below(1 << 30);
        let net = synthetic_decoder(dp, seed, &cfg).map_err(|e| e.to_string())?;
        let prepared = Arc::new(PreparedModel::prepare_decoder(
            &net.nodes,
            net.step_nodes.as_ref().expect("decoder step graph"),
        ));
        let mut engine = EngineMachine::new(&prepared);
        let tokens: Vec<Tensor> = (0..total)
            .map(|_| {
                let data: Vec<f32> = (0..d).map(|_| rng.range(-2.0, 2.0)).collect();
                Tensor { h: 1, w: 1, c: d, data }
            })
            .collect();
        let mut prefix_data: Vec<f32> = Vec::new();
        for (t, tok) in tokens.iter().enumerate() {
            let step = engine.run_step(1, tok);
            prefix_data.extend_from_slice(&tok.data);
            // one-shot twin at this prefix length (same rng stream =>
            // same weights), last row must equal the cached step
            let sub = DecoderCfg { seq: t + 1, ..cfg };
            let net_t = synthetic_decoder(dp, seed, &sub).map_err(|e| e.to_string())?;
            let full = run_network(
                &net_t.nodes,
                &Tensor { h: 1, w: t + 1, c: d, data: prefix_data.clone() },
            );
            if step.output.data[..] != full.output.data[t * d..(t + 1) * d] {
                return Err(format!(
                    "step {t} mismatch (dp={} heads={heads} dh={dh} \
                     prefix={prefix} steps={steps} seed={seed})",
                    dp.label()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use soniq::util::json::{parse, Json};
    check("json-roundtrip", 300, |rng| {
        // generate a random value tree
        fn gen(rng: &mut Rng, depth: u32) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 1),
                2 => Json::Num((rng.below(2_000_000) as f64 - 1e6) / 64.0),
                3 => {
                    let n = rng.below(10) as usize;
                    Json::Str((0..n).map(|_| *rng.choice(&['a', 'é', '"', '\\', '\n', 'z'])).collect())
                }
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..rng.below(5) {
                        m.insert(format!("k{i}"), gen(rng, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = parse(&text).map_err(|e| format!("parse failed: {e} on {text}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

/// The observability histogram's accuracy contract: against an exact
/// sort of the recorded values, every reported quantile lands in
/// `[exact, exact * 1.125]` — values 0..8 are exact, and above that a
/// log bucket with 8 sub-buckets per octave overshoots by at most one
/// sub-bucket width (12.5%).
#[test]
fn prop_loghist_quantiles_within_bucket_bounds() {
    use soniq::serve::LogHist;
    check("loghist-quantile", 400, |rng| {
        let h = LogHist::new();
        let n = 1 + rng.below(400) as usize;
        let mut vals: Vec<u64> = Vec::with_capacity(n);
        for _ in 0..n {
            // span the whole range: the exact small buckets, mid-range
            // log buckets, octave boundaries, and the u64 extremes
            let v = match rng.below(4) {
                0 => rng.below(8),
                1 => rng.below(100_000),
                2 => 1u64 << rng.below(63),
                _ => u64::MAX - rng.below(1 << 20),
            };
            h.record(v);
            vals.push(v);
        }
        vals.sort_unstable();
        if h.count() != n as u64 {
            return Err(format!("count {} != {n}", h.count()));
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let got = h.quantile(q);
            let rank = (q * (n - 1) as f64).round() as usize;
            let exact = vals[rank] as f64;
            if got < exact || got > exact * 1.125 {
                return Err(format!(
                    "q={q} n={n}: hist {got} outside [{exact}, {}]",
                    exact * 1.125
                ));
            }
        }
        Ok(())
    });
}

/// The iteration-level scheduling contract: any interleaving of
/// decode sessions — unequal lengths, sessions admitted mid-flight,
/// sessions retired the moment they finish — served through a worker
/// pool must replay every session's steps bit-identically (and in
/// submission order) against a lone [`EngineMachine`] running the same
/// per-session token streams.
#[test]
fn prop_iteration_scheduled_decode_bit_identical_to_engine() {
    use soniq::coordinator::{synthetic_decoder, DecoderCfg, DesignPoint};
    use soniq::serve::{
        BatchConfig, Completion, EngineMachine, PreparedModel, ServeConfig, Server, SessionId,
    };
    use std::collections::HashMap;
    use std::sync::Arc;
    use std::time::Duration;
    check("iter-decode", 40, |rng| {
        let heads = *rng.choice(&[1usize, 2]);
        let dh = 2usize;
        let d = heads * dh;
        let dp = if rng.below(2) == 0 { DesignPoint::Uniform(4) } else { DesignPoint::Patterns(8) };
        let cfg =
            DecoderCfg { seq: 8, d_model: d, heads, ffn: d * 2, blocks: 1, max_positions: 16 };
        let seed = rng.below(1 << 30);
        let net = synthetic_decoder(dp, seed, &cfg).map_err(|e| e.to_string())?;
        let prepared = Arc::new(PreparedModel::prepare_decoder(
            &net.nodes,
            net.step_nodes.as_ref().expect("decoder step graph"),
        ));
        let scfg = ServeConfig {
            workers: 1 + rng.below(2) as usize,
            batch: BatchConfig {
                max_batch: 1 + rng.below(4) as usize,
                max_delay: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        };
        let mut server = Server::start(Arc::clone(&prepared), &scfg);

        let n_sessions = 1 + rng.below(4) as usize;
        let lens: Vec<usize> = (0..n_sessions).map(|_| 1 + rng.below(8) as usize).collect();
        let tokens: Vec<Vec<Tensor>> = lens
            .iter()
            .map(|&len| {
                (0..len)
                    .map(|_| {
                        let data: Vec<f32> = (0..d).map(|_| rng.range(-2.0, 2.0)).collect();
                        Tensor { h: 1, w: 1, c: d, data }
                    })
                    .collect()
            })
            .collect();

        // half the sessions open up front; the rest are admitted
        // mid-flight, the first time the interleave picks them
        let mut sids: Vec<Option<SessionId>> = vec![None; n_sessions];
        let mut closed = vec![false; n_sessions];
        for s in sids.iter_mut().take(n_sessions.div_ceil(2)) {
            *s = Some(server.open_session());
        }
        let total: usize = lens.iter().sum();
        let mut next_step = vec![0usize; n_sessions];
        let mut submitted: Vec<(u64, usize, usize)> = Vec::new(); // (id, session, step)
        while submitted.len() < total {
            let open: Vec<usize> =
                (0..n_sessions).filter(|&si| next_step[si] < lens[si]).collect();
            let si = *rng.choice(&open);
            let sid = match sids[si] {
                Some(sid) => sid,
                None => {
                    let sid = server.open_session();
                    sids[si] = Some(sid);
                    sid
                }
            };
            let t = next_step[si];
            submitted.push((server.submit_step(sid, tokens[si][t].clone()), si, t));
            next_step[si] += 1;
            // sometimes retire a finished session immediately, while
            // the others are still decoding
            if next_step[si] == lens[si] && rng.below(2) == 0 {
                server.close_session(sid);
                closed[si] = true;
            }
        }
        for si in 0..n_sessions {
            if !closed[si] {
                server.close_session(sids[si].expect("every session served a step"));
            }
        }
        let done = server.shutdown();
        if server.faults().is_some() {
            return Err("serving threads died".into());
        }
        if done.len() != total {
            return Err(format!("{} completions for {total} steps", done.len()));
        }

        // oracle: one lone engine, same per-session submission order
        let mut engine = EngineMachine::new(&prepared);
        let by_id: HashMap<u64, &Completion> = done.iter().map(|c| (c.id, c)).collect();
        for &(id, si, t) in &submitted {
            let want = engine.run_step(si as u64, &tokens[si][t]);
            let got = by_id.get(&id).ok_or(format!("step id {id} never completed"))?;
            if got.session != sids[si].map(|s| s.0) {
                return Err(format!("id {id} completed under the wrong session"));
            }
            if got.output.data != want.output.data {
                return Err(format!(
                    "session {si} step {t} diverged (sessions={n_sessions} \
                     lens={lens:?} workers={} max_batch={} seed={seed})",
                    scfg.workers, scfg.batch.max_batch
                ));
            }
        }
        Ok(())
    });
}

/// The paged-KV contract: any interleaving of paged decode sessions —
/// random page sizes, page-boundary-straddling prefixes, sessions
/// admitted mid-flight, sessions closed early, spill/fault-back round
/// trips under a tight page budget, and the exact-tier V case
/// (`v_bits == pos_prec`) — must be bit-identical to the same token
/// streams through a legacy growable engine, and the pool's books must
/// satisfy `used + spilled == Σ_sessions Σ_slots ceil(len / P_slot)`
/// after every single step.
#[test]
fn prop_paged_decode_bit_identical_to_growable() {
    use soniq::coordinator::{synthetic_decoder, DecoderCfg, DesignPoint};
    use soniq::serve::{EngineMachine, KvPolicy, KvPoolCfg, PreparedModel};
    use std::sync::Arc;
    let (mut spills, mut faults, mut straddled) = (0u64, 0u64, 0u64);
    check("paged-decode", 200, |rng| {
        // a third of the cases push one session past the aligned page
        // size (one packed V chunk), covering multi-page staging; the
        // rest stay short and cover small-page geometry + policy churn
        let long = rng.below(3) == 0;
        let heads = if long { 1 } else { *rng.choice(&[1usize, 2]) };
        let dh = 2usize;
        let d = heads * dh;
        // long cases need pos_prec 4 (32-position chunks): a 33-step
        // session then spans two pages even at the smallest page size
        let dp = match if long { 1 } else { rng.below(3) } {
            0 => DesignPoint::Uniform(2),
            1 => DesignPoint::Uniform(4),
            _ => DesignPoint::Patterns(8),
        };
        let max_positions = if long { 48 } else { 16 };
        let cfg =
            DecoderCfg { seq: 8, d_model: d, heads, ffn: d * 2, blocks: 1, max_positions };
        let seed = rng.below(1 << 30);
        let net = synthetic_decoder(dp, seed, &cfg).map_err(|e| e.to_string())?;
        let prepared = Arc::new(PreparedModel::prepare_decoder(
            &net.nodes,
            net.step_nodes.as_ref().expect("decoder step graph"),
        ));
        let step = prepared.step.as_ref().expect("decoder step model");

        let n_sessions = 1 + rng.below(3) as usize;
        let lens: Vec<usize> = (0..n_sessions)
            .map(|si| {
                if long && si == 0 {
                    33 + rng.below(4) as usize
                } else {
                    1 + rng.below(6) as usize
                }
            })
            .collect();
        // half the cases store V at the exact tier (== compute
        // precision), which must stay bit-identical too
        let v_bits = if rng.below(2) == 0 { None } else { Some(step.slot_geoms[0].pos_prec) };
        let kv = KvPoolCfg {
            page_positions: *rng.choice(&[1usize, 2, 3, 5, 8, 16, 32]),
            pages_per_worker: if rng.below(2) == 0 {
                None
            } else {
                Some(1 + rng.below(2) as usize)
            },
            policy: KvPolicy::Spill,
            v_bits,
        };
        let skv = kv.session_cfg();
        let mut paged = EngineMachine::new(&prepared);
        paged.set_kv_pool(kv);
        let mut oracle = EngineMachine::new(&prepared);

        let tokens: Vec<Vec<Tensor>> = lens
            .iter()
            .map(|&len| {
                (0..len)
                    .map(|_| {
                        let data: Vec<f32> = (0..d).map(|_| rng.range(-2.0, 2.0)).collect();
                        Tensor { h: 1, w: 1, c: d, data }
                    })
                    .collect()
            })
            .collect();

        // random interleave: sessions admit mid-flight (the engine
        // starts one at its first step) and may retire early
        let total: usize = lens.iter().sum();
        let mut done = vec![0usize; n_sessions];
        let mut closed = vec![false; n_sessions];
        let mut served = 0usize;
        while served < total {
            let live: Vec<usize> = (0..n_sessions).filter(|&x| done[x] < lens[x]).collect();
            let si = *rng.choice(&live);
            let t = done[si];
            let got = paged.run_step(si as u64, &tokens[si][t]);
            let want = oracle.run_step(si as u64, &tokens[si][t]);
            if got.output.data != want.output.data {
                return Err(format!(
                    "session {si} step {t} diverged (dp={} P={} budget={:?} \
                     v_bits={v_bits:?} seed={seed})",
                    dp.label(),
                    kv.page_positions,
                    kv.pages_per_worker
                ));
            }
            done[si] += 1;
            served += 1;
            if done[si] == lens[si] && rng.below(2) == 0 {
                paged.end_session(si as u64);
                oracle.end_session(si as u64);
                closed[si] = true;
            }
            // exact accounting at every snapshot, wherever the pages
            // currently live (resident or spilled)
            let s = paged.kv_pool_stats().expect("paged engine has a pool");
            let want_pages: usize = (0..n_sessions)
                .filter(|&x| done[x] > 0 && !closed[x])
                .map(|x| {
                    step.slot_geoms
                        .iter()
                        .map(|sg| sg.page_geom(&skv).pages_for(done[x]))
                        .sum::<usize>()
                })
                .sum();
            if s.used + s.spilled_pages != want_pages {
                return Err(format!(
                    "books off after session {si} step {t}: used {} + spilled {} \
                     != {want_pages} (P={} seed={seed})",
                    s.used, s.spilled_pages, kv.page_positions
                ));
            }
            // Spill keeps residency within budget while other sessions
            // are reclaimable; one session may overcommit alone
            if let Some(b) = kv.pages_per_worker {
                let own: usize = step
                    .slot_geoms
                    .iter()
                    .map(|sg| sg.page_geom(&skv).pages_for(done[si]))
                    .sum();
                if s.used > b.max(own) {
                    return Err(format!(
                        "residency {} over budget {b} with reclaimable victims \
                         (own={own} seed={seed})",
                        s.used
                    ));
                }
            }
        }
        for (si, c) in closed.iter().enumerate() {
            if !c {
                paged.end_session(si as u64);
            }
        }
        let s = paged.kv_pool_stats().expect("paged engine has a pool");
        if s.used != 0 || s.spilled_pages != 0 {
            return Err(format!(
                "pages leaked at close: used {} spilled {} (seed={seed})",
                s.used, s.spilled_pages
            ));
        }
        spills += s.spills;
        faults += s.faults;
        straddled += u64::from(long);
        Ok(())
    });
    assert!(straddled > 0, "sweep never covered a page-boundary-straddling prefix");
    assert!(spills > 0 && faults > 0, "sweep never exercised a spill/fault-back round trip");
}

/// The low-precision V tier's accuracy contract: storing V below
/// compute precision is a *storage* decision, so decode under it must
/// not depend on the page size (byte-identical staging) or on spill
/// round trips — and against the compute-precision oracle the error
/// must stay bounded (no blowups, no NaNs) while being measurably
/// nonzero somewhere in the sweep (the tier really changes the bytes).
#[test]
fn prop_low_v_tier_page_invariant_and_bounded_error() {
    use soniq::coordinator::{synthetic_decoder, DecoderCfg, DesignPoint};
    use soniq::serve::{EngineMachine, KvPolicy, KvPoolCfg, PreparedModel};
    use std::sync::Arc;
    let mut total_err = 0f64;
    check("v-tier", 150, |rng| {
        let heads = *rng.choice(&[1usize, 2]);
        let dh = 2usize;
        let d = heads * dh;
        let cfg =
            DecoderCfg { seq: 8, d_model: d, heads, ffn: d * 2, blocks: 1, max_positions: 16 };
        let seed = rng.below(1 << 30);
        let net = synthetic_decoder(DesignPoint::Uniform(4), seed, &cfg)
            .map_err(|e| e.to_string())?;
        let prepared = Arc::new(PreparedModel::prepare_decoder(
            &net.nodes,
            net.step_nodes.as_ref().expect("decoder step graph"),
        ));
        let v_bits = Some(*rng.choice(&[1u8, 2]));
        let pool = |page_positions: usize, budget: Option<usize>| KvPoolCfg {
            page_positions,
            pages_per_worker: budget,
            policy: KvPolicy::Spill,
            v_bits,
        };
        // engine A runs a 1-page budget plus a decoy session, forcing
        // the measured session through spill/fault-back; engine B is
        // unbounded at a different page size
        let mut a = EngineMachine::new(&prepared);
        a.set_kv_pool(pool(1 + rng.below(8) as usize, Some(1)));
        let mut b = EngineMachine::new(&prepared);
        b.set_kv_pool(pool(9 + rng.below(24) as usize, None));
        let mut oracle = EngineMachine::new(&prepared);

        let steps = 2 + rng.below(9) as usize;
        let tok = |rng: &mut Rng| {
            let data: Vec<f32> = (0..d).map(|_| rng.range(-2.0, 2.0)).collect();
            Tensor { h: 1, w: 1, c: d, data }
        };
        for t in 0..steps {
            let x = tok(rng);
            let got_a = a.run_step(0, &x);
            // decoy step evicts session 0's pages from engine A's pool
            let decoy = tok(rng);
            a.run_step(1, &decoy);
            let got_b = b.run_step(0, &x);
            if got_a.output.data != got_b.output.data {
                return Err(format!(
                    "step {t}: low-V decode depends on page size or spill \
                     round trips (v_bits={v_bits:?} seed={seed})"
                ));
            }
            let want = oracle.run_step(0, &x);
            for (g, w) in got_a.output.data.iter().zip(&want.output.data) {
                if !g.is_finite() {
                    return Err(format!("step {t}: non-finite output {g} (seed={seed})"));
                }
                let err = (*g as f64 - *w as f64).abs();
                // generous stability envelope: tiny net, inputs in
                // [-2, 2] — a coarser V tier perturbs outputs, it must
                // not blow them up
                if err > 64.0 {
                    return Err(format!(
                        "step {t}: error {err} vs compute-precision oracle \
                         (v_bits={v_bits:?} seed={seed})"
                    ));
                }
                total_err += err;
            }
        }
        Ok(())
    });
    assert!(
        total_err > 0.0,
        "a sub-compute V tier must measurably perturb decode somewhere in the sweep"
    );
}
