//! Minimal offline substitute for the `anyhow` crate: an owned error type
//! carrying a context chain, the `anyhow!` / `bail!` / `ensure!` macros,
//! and the `Context` extension trait. Only the API surface this workspace
//! uses is implemented; semantics match anyhow closely enough that the
//! real crate can be dropped in without source changes.

use std::fmt;

/// An error wrapping a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn conversion_and_context() {
        let e = io_fail().with_context(|| "loading config").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.starts_with("loading config: "), "{msg}");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(3).unwrap(), 6);
        assert!(format!("{}", f(-1).unwrap_err()).contains("negative input"));
        assert!(format!("{}", f(11).unwrap_err()).contains("too big"));
        let e: Error = anyhow!("plain {}", 42);
        assert_eq!(format!("{e}"), "plain 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
