//! Fig. 8 harness: accuracy, relative speedup (normalized to U4) and
//! bits-per-parameter for every {network, design point}.
//!
//! Accuracy/bpp come from training the scaled models through PJRT; the
//! run-time axis is simulated BOTH on the scaled models and on the
//! paper-scale (full-width) shape tables, where the vectorization effects
//! the paper measures actually bite (see DESIGN.md).
//!
//!     cargo run --release --example fig8_runtime -- [--quick]
//!         [--models resnet18,mobilenetv2,shufflenetv2]
//!         [--designs FP32,INT8,U4,U2,P4,P8,P45]

use anyhow::Result;
use soniq::coordinator::{run_design_point, simulate_paper_scale, DesignPoint, TrainCfg};
use soniq::util::cli::Args;

fn parse_design(s: &str) -> DesignPoint {
    match s {
        "FP32" => DesignPoint::Fp32,
        "INT8" => DesignPoint::Int8,
        "U2" => DesignPoint::Uniform(2),
        "U4" => DesignPoint::Uniform(4),
        "P4" => DesignPoint::Patterns(4),
        "P8" => DesignPoint::Patterns(8),
        "P45" => DesignPoint::Patterns(45),
        other => panic!("unknown design {other}"),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let models = args.get_or(
        "models",
        if quick { "tinynet" } else { "resnet18,mobilenetv2,shufflenetv2" },
    );
    let designs = args.get_or("designs", "FP32,INT8,U4,U2,P4,P8,P45");
    let cfg = TrainCfg {
        p1_steps: args.get_usize("p1-steps", if quick { 30 } else { 100 }),
        p2_steps: args.get_usize("p2-steps", if quick { 30 } else { 100 }),
        lr: args.get_f32("lr", 0.05),
        lambda: args.get_f32("lambda", 1e-7),
        eval_batches: args.get_usize("eval-batches", if quick { 2 } else { 4 }),
        seed: 0,
    };

    println!("Fig. 8 — accuracy / relative speedup (vs U4) / bpp\n");
    for model in models.split(',') {
        let mut rows = Vec::new();
        for d in designs.split(',') {
            eprintln!("== {model} / {d} ==");
            let dp = parse_design(d);
            let m = run_design_point("artifacts", model, dp, &cfg)?;
            // paper-scale timing (skip for tinynet which has no table)
            let paper_cycles = if model != "tinynet" {
                let (total, _) = simulate_paper_scale(model, dp, &m.layer_fractions);
                Some(total.cycles())
            } else {
                None
            };
            rows.push((m, paper_cycles));
        }
        let u4_small = rows.iter().find(|(m, _)| m.design == "U4").map(|(m, _)| m.cycles).unwrap_or(1);
        let u4_paper = rows
            .iter()
            .find(|(m, _)| m.design == "U4")
            .and_then(|(_, c)| *c)
            .unwrap_or(1);
        println!("\n{model}:");
        println!(
            "{:<6} {:>9} {:>7} {:>16} {:>10} {:>16} {:>10}",
            "design", "accuracy", "bpp", "cycles(scaled)", "speedup", "cycles(paper)", "speedup"
        );
        for (m, pc) in &rows {
            let s1 = u4_small as f64 / m.cycles as f64;
            let (c2, s2) = match pc {
                Some(c) => (format!("{c}"), format!("{:.2}", u4_paper as f64 / *c as f64)),
                None => ("-".into(), "-".into()),
            };
            println!(
                "{:<6} {:>9.4} {:>7.2} {:>16} {:>10.2} {:>16} {:>10}",
                m.design, m.accuracy, m.bpp, m.cycles, s1, c2, s2
            );
        }
    }
    println!("\nfig8_runtime OK");
    Ok(())
}
