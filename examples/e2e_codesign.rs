//! End-to-end co-design driver (the repo's canonical full-system run,
//! recorded in EXPERIMENTS.md): trains a real small workload through all
//! three layers — SASMOL phase I (noise-injected precision search) and
//! phase II (pattern-matched QAT) execute as AOT-compiled JAX+Pallas
//! artifacts under the rust coordinator via PJRT; the trained ULFlexiNet
//! is then pattern-matched (Problem 1 + Algorithm 3), code-generated
//! (Algorithm 4) and timed on the configurable SIMD simulator, with the
//! FP32 and U4 reference points for context.
//!
//!     cargo run --release --example e2e_codesign -- \
//!         [--model resnet18] [--p1-steps 150] [--p2-steps 150] [--quick]

use anyhow::Result;
use soniq::coordinator::{print_table, run_design_point, DesignPoint, TrainCfg};
use soniq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let model = args.get_or("model", if quick { "tinynet" } else { "resnet18" });
    let cfg = TrainCfg {
        p1_steps: args.get_usize("p1-steps", if quick { 40 } else { 150 }),
        p2_steps: args.get_usize("p2-steps", if quick { 40 } else { 150 }),
        lr: args.get_f32("lr", 0.05),
        lambda: args.get_f32("lambda", 1e-7),
        eval_batches: args.get_usize("eval-batches", 4),
        seed: args.get_usize("seed", 0) as u32,
    };
    println!("== SONIQ end-to-end co-design: {model} ==");
    println!("schedule: phase I {} steps, phase II {} steps, lr {}, lambda {:e}\n",
        cfg.p1_steps, cfg.p2_steps, cfg.lr, cfg.lambda);

    let mut rows = Vec::new();
    for dp in [DesignPoint::Fp32, DesignPoint::Uniform(4), DesignPoint::Patterns(4)] {
        eprintln!("--- design point {} ---", dp.label());
        let m = run_design_point("artifacts", &model, dp, &cfg)?;
        // loss curve (downsampled)
        let h = &m.loss_history;
        if !h.is_empty() {
            print!("loss curve {} ({} steps): ", dp.label(), h.len());
            let stride = (h.len() / 12).max(1);
            for (i, l) in h.iter().enumerate().step_by(stride) {
                print!("{i}:{l:.3} ");
            }
            println!("-> final {:.4}", h.last().unwrap());
        }
        rows.push(m);
    }
    println!();
    print_table(&rows, Some("U4"));

    // headline summary (paper abstract: 10-20x vs FP32, accuracy parity)
    let fp = rows.iter().find(|m| m.design == "FP32").unwrap();
    let u4 = rows.iter().find(|m| m.design == "U4").unwrap();
    let p4 = rows.iter().find(|m| m.design == "P4").unwrap();
    println!("\nheadline (scaled testbed):");
    println!(
        "  U4 vs FP32: {:.2}x run-time, {:.2}x energy, {:.1}x size, accuracy {:+.3}",
        fp.cycles as f64 / u4.cycles as f64,
        fp.energy_pj / u4.energy_pj,
        32.0 / u4.bpp,
        u4.accuracy - fp.accuracy
    );
    println!(
        "  P4 vs U4:   {:.2}x run-time, {:.2}x size, accuracy {:+.3}",
        u4.cycles as f64 / p4.cycles as f64,
        u4.bpp / p4.bpp,
        p4.accuracy - u4.accuracy
    );
    println!("\ne2e_codesign OK");
    Ok(())
}
