//! Inference-simulation report: Table IV parameters, per-layer cycle /
//! instruction / cache breakdown for one model + design point, on both
//! the scaled trained model and the paper-scale shape table.
//!
//!     cargo run --release --example inference_sim -- \
//!         [--model resnet18] [--design U4|P4|FP32|INT8]

use anyhow::Result;
use soniq::coordinator::{paperscale, simulate_paper_scale, DesignPoint};
use soniq::sim::cache::LatencyConfig;
use soniq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "resnet18");
    let design = args.get_or("design", "U4");
    let dp = match design.as_str() {
        "FP32" => DesignPoint::Fp32,
        "INT8" => DesignPoint::Int8,
        "U2" => DesignPoint::Uniform(2),
        "U4" => DesignPoint::Uniform(4),
        "P4" => DesignPoint::Patterns(4),
        "P8" => DesignPoint::Patterns(8),
        "P45" => DesignPoint::Patterns(45),
        other => anyhow::bail!("unknown design {other}"),
    };

    let lat = LatencyConfig::default();
    println!("Table IV simulation parameters (gem5-substitute):");
    println!("  CPU: dual-issue front end, decoupled vector ALU/memory pipes, 2 GHz");
    println!("  L1 I-cache: 16KB 4-way 64B lines;  L1 D-cache: 64KB 4-way");
    println!("  L2: 256KB 8-way; latencies L1 {} / L2 {} / mem {} cycles\n", lat.l1_hit, lat.l2_hit, lat.mem);

    // uniform fractions placeholder for P-points when run standalone
    let shapes = paperscale::shapes_for(&model);
    let fractions: Vec<(String, f64, f64)> =
        shapes.iter().map(|s| (s.name.clone(), 0.3, 0.4)).collect();
    let (total, per_layer) = simulate_paper_scale(&model, dp, &fractions);

    println!("{model} @ {design} (paper-scale shapes, batch-1 inference):");
    println!("{:<16} {:>12}", "layer", "cycles");
    for (name, cyc) in &per_layer {
        println!("{name:<16} {cyc:>12}");
    }
    println!("{:-<30}", "");
    println!("{:<16} {:>12}", "total", total.cycles());
    println!(
        "\ninstrs {}  (vmac {}, vmul {}, loads {}, stores {})",
        total.instrs, total.vmac + total.vfma32 + total.vmac_i8, total.vmul, total.loads, total.stores
    );
    println!(
        "cache: L1 hits {}, L2 hits {}, mem {};  energy {:.1} uJ;  {:.3} ms @ 2 GHz",
        total.l1_hits,
        total.l2_hits,
        total.mem_accesses,
        total.energy_pj / 1e6,
        total.cycles() as f64 / 2e9 * 1e3
    );
    println!("\ninference_sim OK");
    Ok(())
}
