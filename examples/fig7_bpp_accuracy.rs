//! Fig. 7 harness: bits-per-parameter vs accuracy for every
//! configuration (FP32, U4, U2, P4, P8, P45) — the size/accuracy
//! trade-off scatter the paper plots.
//!
//!     cargo run --release --example fig7_bpp_accuracy -- [--quick]

use anyhow::Result;
use soniq::coordinator::{run_design_point, DesignPoint, TrainCfg};
use soniq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let models = args.get_or(
        "models",
        if quick { "tinynet" } else { "resnet18,mobilenetv2,shufflenetv2" },
    );
    let cfg = TrainCfg {
        p1_steps: args.get_usize("p1-steps", if quick { 30 } else { 100 }),
        p2_steps: args.get_usize("p2-steps", if quick { 30 } else { 100 }),
        ..TrainCfg::default()
    };
    println!("Fig. 7 — bpp vs accuracy per configuration\n");
    for model in models.split(',') {
        println!("{model}:");
        println!("{:<6} {:>7} {:>9}", "design", "bpp", "accuracy");
        let mut pts = Vec::new();
        for dp in [
            DesignPoint::Fp32,
            DesignPoint::Uniform(4),
            DesignPoint::Uniform(2),
            DesignPoint::Patterns(4),
            DesignPoint::Patterns(8),
            DesignPoint::Patterns(45),
        ] {
            eprintln!("== {model} / {} ==", dp.label());
            let m = run_design_point("artifacts", &model, dp, &cfg)?;
            println!("{:<6} {:>7.2} {:>9.4}", m.design, m.bpp, m.accuracy);
            pts.push((m.design.clone(), m.bpp, m.accuracy));
        }
        // trend checks the paper reports: U4 ~ FP32 parity; U2 below U4;
        // P-points smaller than U4
        let get = |d: &str| pts.iter().find(|(n, _, _)| n == d).unwrap().2;
        let bpp = |d: &str| pts.iter().find(|(n, _, _)| n == d).unwrap().1;
        println!(
            "  trends: U4-FP32 accuracy delta {:+.3}; U2-U4 delta {:+.3}; P4 bpp {:.2} (vs U4 {:.2})\n",
            get("U4") - get("FP32"),
            get("U2") - get("U4"),
            bpp("P4"),
            bpp("U4"),
        );
    }
    println!("fig7_bpp_accuracy OK");
    Ok(())
}
