//! Fig. 9 harness: per-layer average trained bits for the P-design
//! points (ShuffleNetV2 in the paper; any model here) — the "later layers
//! go low-precision" profile behind Key Finding 4.
//!
//!     cargo run --release --example fig9_layer_bpp -- [--model shufflenetv2]

use anyhow::Result;
use soniq::coordinator::{run_design_point, DesignPoint, TrainCfg};
use soniq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let model = args.get_or("model", if quick { "tinynet" } else { "shufflenetv2" });
    let cfg = TrainCfg {
        p1_steps: args.get_usize("p1-steps", if quick { 30 } else { 100 }),
        p2_steps: args.get_usize("p2-steps", if quick { 20 } else { 60 }),
        ..TrainCfg::default()
    };
    println!("Fig. 9 — per-layer average bits per parameter ({model})\n");
    let mut by_design = Vec::new();
    for dp in [DesignPoint::Patterns(4), DesignPoint::Patterns(8), DesignPoint::Patterns(45)] {
        eprintln!("== {} ==", dp.label());
        let m = run_design_point("artifacts", &model, dp, &cfg)?;
        by_design.push((dp.label(), m.layer_bpp));
    }
    let names: Vec<String> = by_design[0].1.iter().map(|(n, _)| n.clone()).collect();
    print!("{:<14}", "layer");
    for (d, _) in &by_design {
        print!(" {d:>6}");
    }
    println!();
    for (i, name) in names.iter().enumerate() {
        print!("{name:<14}");
        for (_, series) in &by_design {
            print!(" {:>6.2}", series[i].1);
        }
        println!();
    }
    // bar-chart sketch for the P4 series
    println!("\nP4 profile:");
    for (name, b) in &by_design[0].1 {
        let bars = "#".repeat((b * 10.0).round() as usize);
        println!("  {name:<14} {b:>5.2} {bars}");
    }
    println!("\nfig9_layer_bpp OK");
    Ok(())
}
