//! Quickstart: the smallest end-to-end tour of the SONIQ/SySMOL stack.
//!
//! Loads the TinyNet artifacts, trains a uniform-4-bit network for a few
//! PJRT steps, evaluates accuracy, then code-generates and simulates one
//! inference on the configurable SIMD architecture — printing Table-II
//! patterns and Table-V hardware costs along the way.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use soniq::coordinator::netbuild;
use soniq::data::Dataset;
use soniq::hw::{gates, timing};
use soniq::runtime::Runtime;
use soniq::sim::network::{run_network, Tensor};
use soniq::simd::patterns::{all_patterns, design_subset, index_of};
use soniq::smol::pattern_match::Assignment;
use soniq::train::{uniform_prec, Trainer};
use std::collections::HashMap;

fn main() -> Result<()> {
    println!("== SONIQ quickstart ==\n");

    // 1. The architecture: 45 precision patterns (Table II)
    let pats = all_patterns();
    println!("Table II: {} precision patterns for 128-bit vectors", pats.len());
    let p4: Vec<usize> = design_subset(4).iter().map(|p| index_of(p).unwrap()).collect();
    println!("Table III P4 subset (indices): {p4:?}");
    println!(
        "Table V: ALU = {:.0} NAND2-eq gates, P4 control block = {:.0}; \
         critical path {:.0} ps (2 GHz OK: {})\n",
        gates::alu_gates(),
        gates::control_block_gates(4),
        timing::critical_path_ps(),
        timing::meets_timing(2.0, 0.05)
    );

    // 2. Train uniform-4-bit TinyNet through the AOT PJRT artifacts
    let rt = Runtime::load("artifacts", "tinynet", Some(&["phase2_step", "eval_quant"]))?;
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut trainer = Trainer::new(&rt, &dataset)?;
    let prec = uniform_prec(&rt.meta.layers, 4);
    println!("training TinyNet @ uniform 4-bit (QAT via PJRT)...");
    for i in 0..40 {
        let (loss, acc) = trainer.phase2_step(i, &prec, 0.05)?;
        if i % 10 == 0 {
            println!("  step {i:>3}: loss {loss:.4}  batch-acc {acc:.3}");
        }
    }
    let acc = trainer.eval(Some(&prec), 2)?;
    println!("eval accuracy (quantized path, Pallas kernel): {acc:.3}\n");

    // 3. Code-generate + simulate one inference on the SIMD architecture
    let asg: HashMap<String, Assignment> = rt
        .meta
        .layers
        .iter()
        .map(|l| (l.name.clone(), Assignment::uniform(l.cin, 4)))
        .collect();
    let graph = netbuild::build_graph(
        &rt.meta,
        &trainer.state,
        &asg,
        soniq::codegen::DataFormat::Smol,
    )?;
    let img = rt.meta.image;
    let b = dataset.batch(1, 0, 1);
    let input = Tensor { h: img, w: img, c: 3, data: b.images };
    let net = run_network(&graph, &input);
    println!(
        "simulated inference: {} cycles ({:.2} us @ 2 GHz), {:.1} uJ, {} instrs ({} vmac)",
        net.total.cycles(),
        net.total.cycles() as f64 / 2000.0,
        net.total.energy_pj / 1e6,
        net.total.instrs,
        net.total.vmac,
    );
    let pred = net
        .output
        .data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!("sample prediction: class {pred} (label {})", b.labels[0]);
    println!("\nquickstart OK");
    Ok(())
}
