//! Table I harness: SMOL variants under different constraints
//! (ShuffleNetV2 in the paper).
//!
//! Row 1 ("original"): per-channel precisions snapped to the full 1..8
//! grid, no pattern constraint. (The original SMOL is per-*weight*; the
//! AOT artifacts express per-input-channel precision — the closest
//! realizable variant, see EXPERIMENTS.md. Activations are quantized in
//! both rows, per Observation 3's consistency rule.)
//! Row 2 ("system-aware"): precisions restricted to {1,2,4} with
//! input-weight consistency and pattern matching — Algorithm 2/3.
//!
//!     cargo run --release --example table1_smol_variants -- [--quick]

use anyhow::Result;
use soniq::coordinator::{run_design_point, DesignPoint, TrainCfg};
use soniq::data::Dataset;
use soniq::runtime::Runtime;
use soniq::smol::quant;
use soniq::train::{PrecMap, Trainer};
use soniq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let model = args.get_or("model", if quick { "tinynet" } else { "shufflenetv2" });
    let p1 = args.get_usize("p1-steps", if quick { 30 } else { 100 });
    let p2 = args.get_usize("p2-steps", if quick { 30 } else { 100 });
    let lambda = args.get_f32("lambda", 1e-7);

    println!("Table I — SMOL variants ({model})\n");

    // --- Row 1: "original-like" SMOL: 1..8-bit per-channel, no patterns
    let rt = Runtime::load("artifacts", &model, Some(&["phase1_step", "phase2_step", "eval_quant"]))?;
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut tr = Trainer::new(&rt, &dataset)?;
    for i in 0..p1 {
        tr.phase1_step(i, soniq::train::lr_schedule(i, p1, 0.05), lambda)?;
    }
    let s_vecs = tr.state.s_vectors();
    let mut prec = PrecMap::new();
    let mut bits_sum = 0f64;
    let mut elems = 0f64;
    for l in &rt.meta.layers {
        let s = &s_vecs[&l.name];
        let p_ch: Vec<u8> = s
            .iter()
            .map(|&v| (quant::precision_from_s(v) as i32).clamp(1, 8) as u8)
            .collect();
        let epc = if l.groups > 1 { l.k * l.k } else if l.op == "fc" { l.cout } else { l.cout * l.k * l.k };
        for &p in &p_ch {
            bits_sum += p as f64 * epc as f64;
            elems += epc as f64;
        }
        prec.insert(
            l.name.clone(),
            (
                p_ch.iter().map(|&p| quant::step_for(p)).collect(),
                p_ch.iter().map(|&p| quant::qmax_for(p)).collect(),
            ),
        );
    }
    for i in 0..p2 {
        tr.phase2_step(p1 + i, &prec, soniq::train::lr_schedule(i, p2, 0.025))?;
    }
    let acc_orig = tr.eval(Some(&prec), 4)?;
    let bpp_orig = bits_sum / elems;

    // --- Row 2: system-aware SMOL ({1,2,4} + consistency + patterns)
    let cfg = TrainCfg { p1_steps: p1, p2_steps: p2, lambda, ..TrainCfg::default() };
    let m = run_design_point("artifacts", &model, DesignPoint::Patterns(45), &cfg)?;

    println!("{:<44} {:>9} {:>6}", "SMOL variation", "accuracy", "bpp");
    println!("{:<44} {:>9.4} {:>6.2}", "Original-like (1..8-bit channels)", acc_orig, bpp_orig);
    println!("{:<44} {:>9.4} {:>6.2}", "1,2,4 bits & input-weight consistency", m.accuracy, m.bpp);
    println!(
        "\ndelta: accuracy {:+.4}, bpp {:+.2} (paper: -2.9 accuracy, +0.1 bpp at full scale)",
        m.accuracy - acc_orig,
        m.bpp - bpp_orig
    );
    println!("\ntable1_smol_variants OK");
    Ok(())
}
