//! Sec. III-A observations harness (Observations 1-5 + the Huffman
//! metadata analysis):
//!
//!  1. fraction of trained precisions <= 4 bits (paper: > 90%)
//!  2. cost of restricting to {1,2,4} (covered by Table I harness)
//!  3. input-weight consistency (built into Algorithm 2; shown here as
//!     the per-channel s sharing)
//!  4. channel rearrangement -> 3 integers of metadata per layer, vs the
//!     +66.4%-style Huffman overhead for arbitrary per-weight precisions
//!  5. >= 16-bit same-precision runs after rearrangement (paper: > 90%)
//!
//!     cargo run --release --example observations -- [--quick]

use anyhow::Result;
use soniq::data::Dataset;
use soniq::runtime::Runtime;
use soniq::simd::patterns::all_patterns;
use soniq::smol::huffman;
use soniq::smol::pattern_match::pattern_match;
use soniq::smol::quant;
use soniq::smol::stats;
use soniq::train::Trainer;
use soniq::util::cli::Args;
use soniq::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let model = args.get_or("model", if quick { "tinynet" } else { "shufflenetv2" });
    let p1 = args.get_usize("p1-steps", if quick { 30 } else { 100 });

    println!("== Sec. III-A observations ({model}) ==\n");
    let rt = Runtime::load("artifacts", &model, Some(&["phase1_step"]))?;
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut tr = Trainer::new(&rt, &dataset)?;
    for i in 0..p1 {
        tr.phase1_step(i, soniq::train::lr_schedule(i, p1, 0.05), 1e-7)?;
    }
    let s_vecs = tr.state.s_vectors();

    // Observation 1: unconstrained precisions (1..8 grid) mostly <= 4 bits
    let mut all_prec = Vec::new();
    for l in &rt.meta.layers {
        for &v in &s_vecs[&l.name] {
            all_prec.push((quant::precision_from_s(v) as i32).clamp(1, 8) as u8);
        }
    }
    println!(
        "Obs 1: {:.1}% of trained channel precisions are <= 4 bits (paper: > 90%)",
        100.0 * stats::fraction_le_4bits(&all_prec)
    );

    // Observation 4+5: pattern-match, then run-length + metadata analysis
    let mut run_cov = Vec::new();
    for l in &rt.meta.layers {
        let a = pattern_match(&s_vecs[&l.name], &all_patterns());
        run_cov.push(stats::same_precision_run_coverage(&a));
    }
    let avg_cov = run_cov.iter().sum::<f64>() / run_cov.len() as f64;
    println!("Obs 5: {:.1}% of bits lie in >=16-bit same-precision runs after rearrangement (paper: > 90%)", 100.0 * avg_cov);

    // Observation 4 / metadata: pattern scheme (3 ints/layer) vs Huffman-
    // coded per-weight precisions for an original-SMOL-like last layer
    let mut rng = Rng::new(3);
    let last = rt.meta.layers.last().unwrap();
    let n_weights = last.cin * last.cout;
    let stream: Vec<u8> = (0..n_weights.max(4096))
        .map(|_| match rng.below(100) {
            0..=44 => 1u8,
            45..=74 => 2,
            75..=84 => 3,
            85..=91 => 4,
            92..=95 => 5,
            96..=97 => 6,
            98 => 7,
            _ => 8,
        })
        .collect();
    let cost = huffman::metadata_cost(&stream);
    println!(
        "Obs 4: per-weight Huffman metadata = +{:.1}% of data bits (paper: +66.4% on a ResNet last layer); pattern scheme = +{:.3}%",
        100.0 * cost.huffman_overhead(),
        100.0 * cost.pattern_overhead()
    );

    // Per-layer precision histogram
    println!("\nper-layer snapped {{1,2,4}} distribution:");
    for l in &rt.meta.layers {
        let s = &s_vecs[&l.name];
        let snapped: Vec<u8> = s
            .iter()
            .map(|&v| quant::snap_precision(quant::precision_from_s(v)))
            .collect();
        let c = |b: u8| snapped.iter().filter(|&&p| p == b).count();
        println!("  {:<14} 4b:{:>3}  2b:{:>3}  1b:{:>3}", l.name, c(4), c(2), c(1));
    }
    println!("\nobservations OK");
    Ok(())
}
